"""Property tests (hypothesis) for the paper's partitioning math (§3.3) and
the EDM machinery (§2.1 / App. C)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev extra: pip install -e .[dev]")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import DBConfig  # noqa: E402
from repro.core import edm  # noqa: E402
from repro.core import partition as P  # noqa: E402

db_configs = st.builds(
    DBConfig,
    num_blocks=st.integers(1, 12),
    p_mean=st.floats(-2.0, 1.0),
    p_std=st.floats(0.5, 2.0),
    sigma_min=st.floats(1e-3, 0.05),
    sigma_max=st.floats(10.0, 200.0),
    overlap_gamma=st.floats(0.0, 0.2),
)


@settings(deadline=None, max_examples=60)
@given(db_configs)
def test_equiprob_edges_monotone_and_bounded(db):
    edges = P.sigma_edges(db)
    assert len(edges) == db.num_blocks + 1
    assert np.all(np.diff(edges) < 0), "edges must descend"
    assert edges[0] == pytest.approx(db.sigma_max)
    assert edges[-1] == pytest.approx(db.sigma_min)


@settings(deadline=None, max_examples=60)
@given(db_configs)
def test_equiprob_equal_mass(db):
    """Paper §3.3: every block carries exactly 1/B of the truncated
    p_noise mass."""
    for b in range(db.num_blocks):
        assert P.block_mass(db, b) == pytest.approx(1.0 / db.num_blocks,
                                                    rel=1e-6)


@settings(deadline=None, max_examples=40)
@given(db_configs)
def test_overlap_expands_range(db):
    for b in range(db.num_blocks):
        lo0, hi0 = P.block_sigma_range(db, b, with_overlap=False)
        lo1, hi1 = P.block_sigma_range(db, b, with_overlap=True)
        assert lo1 <= lo0 * (1 + 1e-9) and hi1 >= hi0 * (1 - 1e-9)
        assert lo1 >= db.sigma_min * (1 - 1e-9)
        assert hi1 <= db.sigma_max * (1 + 1e-9)


@settings(deadline=None, max_examples=40)
@given(db_configs, st.integers(2, 100))
def test_sampling_schedule(db, n):
    sched = P.sampling_schedule(db, n)
    assert len(sched) == n + 1
    assert sched[0] == pytest.approx(db.sigma_max)
    assert sched[-1] == 0.0
    assert np.all(np.diff(sched) < 0)
    # every non-final step maps to a valid block
    for s in sched[:-1]:
        b = P.block_of_sigma(db, float(s))
        assert 0 <= b < db.num_blocks


@settings(deadline=None, max_examples=30)
@given(st.integers(1, 64), st.integers(1, 8))
def test_unit_ranges_cover(n_units, B):
    if B > n_units:
        B = n_units
    ranges = P.unit_ranges(n_units, B)
    assert ranges[0][0] == 0
    total = 0
    for i, (s, z) in enumerate(ranges):
        assert z >= 1
        assert s == total
        total += z
    assert total == n_units


def test_unit_ranges_custom_distribution():
    assert P.unit_ranges(12, 3, [2, 4, 6]) == [(0, 2), (2, 4), (6, 6)]
    with pytest.raises(AssertionError):
        P.unit_ranges(12, 3, [2, 4, 5])


@settings(deadline=None, max_examples=40)
@given(st.floats(0.003, 70.0))
def test_preconditioning_identities(sigma):
    """EDM identities: c_skip² + (c_out/σ_data·σ... and w(σ)·c_out² ≡ 1."""
    sd = 0.5
    c_skip, c_out, c_in, c_noise = edm.preconditioning(jnp.float32(sigma), sd)
    w = edm.weighting(jnp.float32(sigma), sd)
    assert float(w * c_out ** 2) == pytest.approx(1.0, rel=1e-4)
    # c_in normalizes input variance: (σ² + σ_d²)·c_in² == 1
    assert float((sigma ** 2 + sd ** 2) * c_in ** 2) == pytest.approx(
        1.0, rel=1e-4)
    assert float(c_noise) == pytest.approx(np.log(sigma) / 4, rel=1e-3,
                                           abs=1e-5)


@settings(deadline=None, max_examples=20)
@given(db_configs)
def test_sigma_sampling_within_block_range(db):
    rng = jax.random.PRNGKey(0)
    for b in range(db.num_blocks):
        q_lo, q_hi = P.block_qrange(db, b)
        s = edm.sample_sigma_in_qrange(rng, (512,), db, q_lo, q_hi)
        lo, hi = P.block_sigma_range(db, b)
        assert float(jnp.min(s)) >= lo * 0.999
        assert float(jnp.max(s)) <= hi * 1.001


def test_block_of_sigma_consistent_with_edges():
    db = DBConfig(num_blocks=4)
    edges = P.sigma_edges(db)
    for b in range(4):
        mid = np.sqrt(edges[b] * edges[b + 1])   # geometric midpoint
        assert P.block_of_sigma(db, mid) == b


def test_euler_step_reaches_denoiser_at_zero():
    z = jnp.ones((2, 3))
    d = jnp.full((2, 3), 5.0)
    out = edm.euler_step(z, d, 1.0, 0.0)
    np.testing.assert_allclose(np.asarray(out), 5.0)


def test_euler_chain_gaussian_analytic():
    """For y ~ N(0, σ_d² I) the optimal denoiser is D(z,σ) = c_skip·z·...
    = σ_d²/(σ_d²+σ²) z. Integrating the PF-ODE from σ_max with that D must
    map N(0, σ_max²+σ_d²) samples to N(0, σ_d²)."""
    sd = 0.5
    db = DBConfig(num_blocks=3, sigma_data=sd)
    sched = P.sampling_schedule(db, 200)
    rng = jax.random.PRNGKey(1)
    n = 20000
    z = jnp.sqrt(db.sigma_max ** 2 + sd ** 2) * jax.random.normal(rng, (n,))
    for i in range(len(sched) - 1):
        s_from, s_to = float(sched[i]), float(sched[i + 1])
        d_hat = (sd ** 2 / (sd ** 2 + s_from ** 2)) * z
        z = edm.euler_step(z, d_hat, s_from, s_to) if s_to > 0 else d_hat
    std = float(jnp.std(z))
    assert abs(std - sd) / sd < 0.05, std
