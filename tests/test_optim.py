"""AdamW vs a plain-numpy oracle + schedule properties (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev extra: pip install -e .[dev]")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.optim import adamw, apply_updates, global_norm, warmup_cosine  # noqa: E402


def numpy_adamw(params, grads, steps, lr, b1, b2, eps, wd):
    m = {k: np.zeros_like(v) for k, v in params.items()}
    v_ = {k: np.zeros_like(v) for k, v in params.items()}
    p = {k: v.copy() for k, v in params.items()}
    for t in range(1, steps + 1):
        for k in p:
            g = grads[k]
            m[k] = b1 * m[k] + (1 - b1) * g
            v_[k] = b2 * v_[k] + (1 - b2) * g * g
            mh = m[k] / (1 - b1 ** t)
            vh = v_[k] / (1 - b2 ** t)
            p[k] -= lr * (mh / (np.sqrt(vh) + eps) + wd * p[k])
    return p


def test_adamw_matches_numpy_oracle():
    rng = np.random.RandomState(0)
    params = {"a": rng.randn(4, 3).astype(np.float32),
              "b": rng.randn(7).astype(np.float32)}
    grads = {"a": rng.randn(4, 3).astype(np.float32),
             "b": rng.randn(7).astype(np.float32)}
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.03
    init, update = adamw(lr, b1, b2, eps, weight_decay=wd)
    p = {k: jnp.asarray(v) for k, v in params.items()}
    g = {k: jnp.asarray(v) for k, v in grads.items()}
    st_ = init(p)
    for _ in range(5):
        upd, st_, _ = update(g, st_, p)
        p = apply_updates(p, upd)
    expect = numpy_adamw(params, grads, 5, lr, b1, b2, eps, wd)
    for k in p:
        np.testing.assert_allclose(np.asarray(p[k]), expect[k], rtol=2e-5,
                                   atol=2e-6)


def test_grad_clipping():
    init, update = adamw(1e-2, grad_clip=1.0)
    p = {"a": jnp.zeros(4)}
    g = {"a": jnp.full(4, 100.0)}
    st_ = init(p)
    _, _, m = update(g, st_, p)
    assert float(m["grad_norm"]) == 200.0
    # after clipping the effective norm is 1 — step bounded by lr
    upd, _, _ = update(g, init(p), p)
    assert float(jnp.max(jnp.abs(upd["a"]))) <= 1.1e-2


@settings(deadline=None, max_examples=30)
@given(st.integers(1, 100), st.integers(101, 1000), st.floats(1e-5, 1e-2))
def test_warmup_cosine_properties(w, total, base):
    lr = warmup_cosine(base, w, total)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(w))) <= base * (1 + 1e-6)
    peak = float(lr(jnp.asarray(w)))
    end = float(lr(jnp.asarray(total)))
    assert end <= peak + 1e-9
    assert end >= base * 0.1 * 0.999  # final_frac floor


def test_global_norm():
    t = {"a": jnp.ones(4), "b": jnp.ones(9) * 2}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(40), rel=1e-6)
