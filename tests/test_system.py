"""End-to-end system behaviour: DB training reaches e2e-comparable loss on a
learnable synthetic LM task; block-wise serving produces on-distribution text;
the distributed dry-run lowers+compiles in a subprocess with a small forced
device count (sharding path exercised for real)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DBConfig
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import DiffusionBlocksModel, train_db, train_e2e
from repro.data import MarkovLM

TINY = ModelConfig(name="tiny", family="dense", n_layers=6, d_model=64,
                   n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=32)

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.slow
def test_db_matches_e2e_on_markov():
    lm = MarkovLM(vocab_size=32, branching=2, seed=5)

    def it(seed):
        rng = np.random.RandomState(seed)
        while True:
            yield jnp.asarray(lm.sample(rng, 16, 32))

    tcfg = TrainConfig(steps=60, lr=2e-3, warmup_steps=6, log_every=0)
    dbm = DiffusionBlocksModel(TINY, DBConfig(num_blocks=3,
                                              overlap_gamma=0.05))
    _, hist_db = train_db(dbm, tcfg, it(1), jax.random.PRNGKey(0),
                          log=lambda *_: None)
    _, hist_e2e = train_e2e(dbm, tcfg, it(1), jax.random.PRNGKey(0),
                            log=lambda *_: None)
    db_last = np.mean([l for _, _, l in hist_db[-10:]])
    e2e_last = np.mean([l for _, _, l in hist_e2e[-10:]])
    db_first = np.mean([l for _, _, l in hist_db[:10]])
    assert db_last < db_first * 0.9            # DB learns
    assert db_last < e2e_last * 3.0            # same ballpark at tiny budget


@pytest.mark.slow
def test_serve_generates_on_distribution():
    lm = MarkovLM(vocab_size=32, branching=2, seed=5)
    dbm = DiffusionBlocksModel(TINY, DBConfig(num_blocks=3,
                                              overlap_gamma=0.05))

    def it():
        rng = np.random.RandomState(1)
        while True:
            yield jnp.asarray(lm.sample(rng, 16, 32))

    tcfg = TrainConfig(steps=120, lr=2e-3, warmup_steps=10, log_every=0)
    params, _ = train_db(dbm, tcfg, it(), jax.random.PRNGKey(0),
                         log=lambda *_: None)
    from repro.launch.serve import generate
    prompts = jnp.asarray(lm.sample(np.random.RandomState(2), 2, 8))
    out = generate(dbm, params, prompts, max_new=16)
    acc = lm.transition_accuracy(np.array(out))
    # random tokens get ~2*branching/V = 12.5%; trained model must beat that
    assert acc > 0.3, acc


def _run_dryrun(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=420)


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape,extra", [
    ("stablelm-1.6b", "train_4k", ("--batch", "16", "--seq", "256")),
    ("phi3.5-moe-42b-a6.6b", "decode_32k", ("--batch", "16", "--seq", "64")),
    ("zamba2-7b", "prefill_32k", ("--batch", "8", "--seq", "128")),
])
def test_dryrun_subprocess_small_mesh(arch, shape, extra, tmp_path):
    """Reduced configs on a forced 8-device (4x2) mesh: proves lower() +
    compile() + sharding rules work end-to-end in a fresh process."""
    r = _run_dryrun("--arch", arch, "--shape", shape, "--reduced",
                    "--mesh", "4x2", "--out", str(tmp_path), *extra)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "dry-run OK" in r.stdout


@pytest.mark.slow
def test_dryrun_subprocess_multipod_small(tmp_path):
    r = _run_dryrun("--arch", "olmo-1b", "--shape", "train_4k", "--reduced",
                    "--mesh", "2x2x2", "--multi-pod", "--out", str(tmp_path),
                    "--batch", "16", "--seq", "128")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "dry-run OK" in r.stdout
