"""Chunked-prefill engine tests: chunk-vs-per-token numerical equivalence
(cache activations and greedy tokens) across all four model families with
uniform and ragged prompts, the flash-prefill Pallas kernel vs the gather
reference, the mixed chunked-prefill/decode continuous scheduler, and the
shared-prefix page cache (hit accounting, copy-on-write, output parity,
recurrent-family rejection)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core import DiffusionBlocksModel
from repro.launch.serve import ContinuousBatcher, generate, get_engine
from repro.nn import attention as A
from repro.nn import cache as KVC

TINY = ModelConfig(name="tiny-prefill", family="dense", n_layers=6,
                   d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                   vocab_size=32)

FAMILY_ARCHS = ["xlstm-125m", "zamba2-7b", "whisper-small",
                "llama-3.2-vision-11b", "h2o-danube-3-4b"]


def make_dbm(cfg=TINY, blocks=3):
    n_units = DiffusionBlocksModel(cfg, DBConfig(num_blocks=1)).model.n_units
    return DiffusionBlocksModel(
        cfg, DBConfig(num_blocks=min(blocks, n_units), overlap_gamma=0.1))


@pytest.fixture(scope="module")
def dbm_params():
    dbm = make_dbm()
    return dbm, dbm.init(jax.random.PRNGKey(0))


def _prefill_both_ways(dbm, params, prompts, plens, *, psz=4, chunk=4,
                       extra=4, impl="auto"):
    """Run the per-token and the chunked prefill over the same pool layout;
    returns ((kv_tok, len_tok), (kv_chunk, len_chunk))."""
    B, S0 = prompts.shape
    pps = KVC.pages_for(S0 + extra, psz)
    kv0 = dbm.model.init_paged_cache(B, 1 + B * pps, psz, "fp32")
    table = KVC.identity_page_table(B, pps)
    kv_a, len_a = kv0, jnp.zeros((B,), jnp.int32)
    for t in range(S0):
        kv_a, len_a = dbm.commit_prompt_token(
            params, kv_a, table, len_a, prompts[:, t:t + 1],
            active=t < plens, precision="fp32", impl=impl)
    kv_b, len_b = kv0, jnp.zeros((B,), jnp.int32)
    for _ in range(-(-S0 // chunk)):
        idx = len_b[:, None] + jnp.arange(chunk)
        tok = jnp.take_along_axis(prompts, jnp.clip(idx, 0, S0 - 1), axis=1)
        kv_b, len_b = dbm.commit_prompt_chunk(
            params, kv_b, table, len_b, tok,
            n_valid=jnp.clip(plens - len_b, 0, chunk), precision="fp32",
            impl=impl)
    return (kv_a, len_a), (kv_b, len_b)


def _assert_caches_close(kv_a, kv_b, atol):
    """Every cache leaf — the intermediate activations of every unit: paged
    attention KV (trash page excluded: both paths dump garbage there) and
    dense recurrent/cross state — must agree."""
    la = jax.tree_util.tree_leaves(kv_a,
                                   is_leaf=lambda x: isinstance(x, KVC.PagedKV))
    lb = jax.tree_util.tree_leaves(kv_b,
                                   is_leaf=lambda x: isinstance(x, KVC.PagedKV))
    checked = 0
    for x, y in zip(la, lb):
        if isinstance(x, KVC.PagedKV):
            page_ax = x.k.ndim - 4          # leading unit axes vary by family
            sel = tuple([slice(None)] * page_ax + [slice(1, None)])
            for u, v in ((x.k, y.k), (x.v, y.v)):
                np.testing.assert_allclose(np.asarray(u[sel], np.float32),
                                           np.asarray(v[sel], np.float32),
                                           atol=atol, rtol=atol)
                checked += 1
        else:
            np.testing.assert_allclose(np.asarray(x, np.float32),
                                       np.asarray(y, np.float32),
                                       atol=atol, rtol=atol)
            checked += 1
    assert checked > 0


# ---------------------------------------------------------------------------
# commit_prompt_chunk == per-token commit scan (cache activations <= 1e-4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ragged", [False, True])
def test_chunk_commit_matches_per_token_dense(dbm_params, ragged):
    dbm, params = dbm_params
    B, S0 = 3, 7
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S0), 0,
                                 TINY.vocab_size)
    plens = (jnp.asarray([7, 3, 5], jnp.int32) if ragged
             else jnp.full((B,), S0, jnp.int32))
    (kv_a, la), (kv_b, lb) = _prefill_both_ways(dbm, params, prompts, plens)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    _assert_caches_close(kv_a, kv_b, atol=1e-4)


@pytest.mark.slow
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
@pytest.mark.parametrize("ragged", [False, True])
def test_chunk_commit_matches_per_token_families(arch, ragged):
    """All four family modules (transformer incl. VLM, hybrid, encdec,
    ssm_model), uniform and ragged: every unit's committed activations
    (paged KV + recurrent/conv/cross state) within 1e-4 of the per-token
    reference scan."""
    cfg = configs.reduced(configs.get_config(arch))
    dbm = make_dbm(cfg, blocks=2)
    params = dbm.init(jax.random.PRNGKey(0))
    B, S0 = 3, 7
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S0), 0,
                                 cfg.vocab_size)
    plens = (jnp.asarray([7, 3, 5], jnp.int32) if ragged
             else jnp.full((B,), S0, jnp.int32))
    (kv_a, la), (kv_b, lb) = _prefill_both_ways(dbm, params, prompts, plens)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    _assert_caches_close(kv_a, kv_b, atol=1e-4)


def test_chunk_commit_kernel_route(dbm_params):
    """impl='kernels' (flash-prefill Pallas kernel, interpret on CPU) agrees
    with the gather-reference route through the full model commit."""
    dbm, params = dbm_params
    B, S0 = 2, 6
    prompts = jax.random.randint(jax.random.PRNGKey(2), (B, S0), 0,
                                 TINY.vocab_size)
    plens = jnp.asarray([6, 4], jnp.int32)
    (_, _), (kv_ref, _) = _prefill_both_ways(dbm, params, prompts, plens,
                                             impl="auto")
    (_, _), (kv_ker, _) = _prefill_both_ways(dbm, params, prompts, plens,
                                             impl="kernels")
    _assert_caches_close(kv_ref, kv_ker, atol=1e-4)


# ---------------------------------------------------------------------------
# flash-prefill kernel vs gather reference (GQA, window, ragged, multi-page)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [None, 5])
@pytest.mark.parametrize("G", [1, 2])
def test_flash_prefill_kernel_matches_ref(window, G):
    rng = np.random.RandomState(0)
    B, C, KV, hd, psz = 3, 6, 2, 16, 4
    dims = A.AttnDims(KV * G, KV, hd)
    lengths = jnp.asarray([0, 3, 9], jnp.int32)
    pps = KVC.pages_for(16, psz)
    pkv = KVC.init_paged_kv(1 + B * pps, psz, dims, jnp.float32)
    table = KVC.identity_page_table(B, pps)
    for t in range(int(jnp.max(lengths))):
        kt = jnp.asarray(rng.randn(B, KV, hd), jnp.float32)
        pkv = KVC.append_paged(pkv, kt, kt * 0.5, table,
                               jnp.minimum(lengths, t), active=t < lengths)
    k_new = jnp.asarray(rng.randn(B, C, KV, hd), jnp.float32)
    v_new = jnp.asarray(rng.randn(B, C, KV, hd), jnp.float32)
    n_valid = jnp.asarray([6, 4, 2], jnp.int32)
    pkv = KVC.append_paged_chunk(pkv, k_new, v_new, table, lengths, n_valid)
    q = jnp.asarray(rng.randn(B, C, KV, G, hd), jnp.float32)
    ref = KVC.attend_prefill(q, pkv, table, lengths, window=window,
                             impl="auto")
    ker = KVC.attend_prefill(q, pkv, table, lengths, window=window,
                             impl="kernels")
    for b in range(B):
        nv = int(n_valid[b])
        if nv:
            np.testing.assert_allclose(np.asarray(ker)[b, :nv],
                                       np.asarray(ref)[b, :nv],
                                       atol=1e-5, rtol=1e-5)


def test_append_paged_chunk_matches_sequential_appends():
    """Non-trash pages after a chunk append must be BIT-identical to C
    sequential per-token appends (ragged: tails redirected to trash)."""
    rng = np.random.RandomState(1)
    B, C, KV, hd, psz = 3, 5, 2, 8, 4
    dims = A.AttnDims(KV, KV, hd)
    lengths = jnp.asarray([0, 3, 7], jnp.int32)
    n_valid = jnp.asarray([5, 3, 0], jnp.int32)
    pps = KVC.pages_for(12, psz)
    pkv0 = KVC.init_paged_kv(1 + B * pps, psz, dims, jnp.float32)
    table = KVC.identity_page_table(B, pps)
    k_new = jnp.asarray(rng.randn(B, C, KV, hd), jnp.float32)
    v_new = jnp.asarray(rng.randn(B, C, KV, hd), jnp.float32)
    chunked = KVC.append_paged_chunk(pkv0, k_new, v_new, table, lengths,
                                     n_valid)
    seq = pkv0
    for t in range(C):
        lt = lengths + jnp.minimum(t, n_valid)
        seq = KVC.append_paged(seq, k_new[:, t], v_new[:, t], table, lt,
                               active=t < n_valid)
    np.testing.assert_array_equal(np.asarray(seq.k[1:]),
                                  np.asarray(chunked.k[1:]))
    np.testing.assert_array_equal(np.asarray(seq.v[1:]),
                                  np.asarray(chunked.v[1:]))


# ---------------------------------------------------------------------------
# generate(): chunked prefill greedy tokens == per-token prefill scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision", ["fp32", "bf16"])
def test_generate_chunked_matches_per_token(dbm_params, precision):
    dbm, params = dbm_params
    prompts = jax.random.randint(jax.random.PRNGKey(3), (4, 10), 0,
                                 TINY.vocab_size)
    plens = np.array([10, 4, 7, 9])
    kw = dict(rng=jax.random.PRNGKey(7), prompt_lengths=plens,
              precision=precision)
    o_tok = generate(dbm, params, prompts, 6, prefill="per-token", **kw)
    o_chk = generate(dbm, params, prompts, 6, prefill="chunked",
                     chunk_size=4, **kw)
    np.testing.assert_array_equal(np.asarray(o_tok), np.asarray(o_chk))


@pytest.mark.slow
@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_generate_chunked_matches_per_token_families(arch):
    cfg = configs.reduced(configs.get_config(arch))
    dbm = make_dbm(cfg, blocks=2)
    params = dbm.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 6), 0,
                                 cfg.vocab_size)
    plens = np.array([3, 6, 4])
    kw = dict(rng=jax.random.PRNGKey(7), prompt_lengths=plens,
              precision="fp32")
    o_tok = generate(dbm, params, prompts, 4, prefill="per-token", **kw)
    o_chk = generate(dbm, params, prompts, 4, prefill="chunked",
                     chunk_size=4, **kw)
    np.testing.assert_array_equal(np.asarray(o_tok), np.asarray(o_chk))


def test_engine_counts_prefill_steps(dbm_params):
    dbm, params = dbm_params
    prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0,
                                 TINY.vocab_size)
    e_tok = get_engine(dbm, precision="fp32", prefill="per-token")
    e_chk = get_engine(dbm, precision="fp32", prefill="chunked",
                       chunk_size=4)
    s0 = e_tok.prefill_steps
    e_tok.generate(params, prompts, 2, jax.random.PRNGKey(0))
    assert e_tok.prefill_steps - s0 == 12      # one serial step per token
    s0 = e_chk.prefill_steps
    e_chk.generate(params, prompts, 2, jax.random.PRNGKey(0))
    assert e_chk.prefill_steps - s0 == 3       # ceil(12 / 4) chunks


# ---------------------------------------------------------------------------
# continuous batching: mixed chunked-prefill/decode scheduling
# ---------------------------------------------------------------------------

def test_continuous_chunked_single_request_matches_static_engine(dbm_params):
    """A lone request on ONE slot consumes the rng stream exactly like the
    static engine (chunk dispatches draw no rng; the denoise z-draw shape is
    the slot count, so it must match the static batch), making its generated
    tokens IDENTICAL to ``generate(prefill="chunked")``."""
    dbm, params = dbm_params
    rs = np.random.RandomState(2)
    prompt = rs.randint(0, TINY.vocab_size, size=7)
    cb = ContinuousBatcher(dbm, params, num_slots=1, max_prompt=8,
                           max_len=16, seg_len=4, page_size=4,
                           prefill="chunked", chunk_size=4, precision="fp32")
    cb.submit(prompt, 6)
    out_cb = cb.run(jax.random.PRNGKey(3))[0].out
    out_static = np.asarray(generate(dbm, params, prompt[None], 6,
                                     rng=jax.random.PRNGKey(3),
                                     prefill="chunked", chunk_size=4,
                                     precision="fp32"))[0, 7:]
    assert out_cb == list(out_static)


def test_continuous_chunked_mixed_scheduling_correctness(dbm_params):
    """Mixed chunked-prefill/decode over a ragged queue: every request
    completes with in-range tokens, the run is deterministic, and all pages
    return to the pool (the per-token scheduler draws a different rng stream
    while committing prompts, so token-level parity is only asserted for the
    single-request case above)."""
    dbm, params = dbm_params
    rs = np.random.RandomState(2)
    reqs = [(rs.randint(0, TINY.vocab_size, size=rs.randint(3, 9)), 5)
            for _ in range(5)]

    def serve():
        cb = ContinuousBatcher(dbm, params, num_slots=2, max_prompt=8,
                               max_len=16, seg_len=4, page_size=4,
                               prefill="chunked", chunk_size=4,
                               precision="fp32")
        for p, n in reqs:
            cb.submit(p, n)
        return [r.out for r in cb.run(jax.random.PRNGKey(3))], cb

    out1, cb = serve()
    out2, _ = serve()
    assert out1 == out2                       # deterministic
    assert all(len(o) == 5 for o in out1)
    assert all(0 <= t < TINY.vocab_size for o in out1 for t in o)
    # all pages reclaimed (no prefix cache -> no retained refs)
    assert len(cb.free_pages) == cb.total_pages - 1
    assert not cb.page_refs


def test_continuous_chunked_ttft_and_steps(dbm_params):
    dbm, params = dbm_params
    cb = ContinuousBatcher(dbm, params, num_slots=2, max_prompt=8,
                           max_len=16, seg_len=4, page_size=4,
                           prefill="chunked", chunk_size=8)
    rs = np.random.RandomState(3)
    for _ in range(4):
        cb.submit(rs.randint(0, TINY.vocab_size, size=8), 4)
    done = cb.run(jax.random.PRNGKey(1))
    assert all(len(r.out) == 4 for r in done)
    assert all(r.ttft is not None and r.ttft >= 0 for r in done)
    # 8-token prompts at chunk_size=8: one serial prefill step per admission
    # wave, never one per token
    assert cb.eng.prefill_steps < 4 * 8


# ---------------------------------------------------------------------------
# shared-prefix page cache
# ---------------------------------------------------------------------------

def _mk_prefix_batcher(dbm, params, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_prompt", 32)
    kw.setdefault("max_len", 48)
    kw.setdefault("seg_len", 4)
    kw.setdefault("page_size", 4)
    kw.setdefault("chunk_size", 8)
    kw.setdefault("precision", "fp32")
    return ContinuousBatcher(dbm, params, prefix_cache=True, **kw)


def test_prefix_cache_second_request_prefills_suffix_only(dbm_params):
    dbm, params = dbm_params
    rs = np.random.RandomState(4)
    sys_p = rs.randint(0, TINY.vocab_size, size=24)
    u1 = rs.randint(0, TINY.vocab_size, size=6)
    u2 = rs.randint(0, TINY.vocab_size, size=6)
    cb = _mk_prefix_batcher(dbm, params)
    cb.submit(np.concatenate([sys_p, u1]), max_new=5)
    cb.run(jax.random.PRNGKey(3))
    steps0 = cb.eng.prefill_steps
    cb.submit(np.concatenate([sys_p, u2]), max_new=5)
    done = cb.run(jax.random.PRNGKey(4))
    req2 = done[0]
    # the whole page-aligned system prefix came from the cache
    assert req2.shared_tokens == 24
    assert cb.prefix.hits == 1
    # 6 remaining tokens at chunk 8 -> ONE chunk step (vs 4 for the full 30)
    assert cb.eng.prefill_steps - steps0 == 1
    # numerical parity with an unshared serve of the same request
    cb2 = ContinuousBatcher(dbm, params, num_slots=2, max_prompt=32,
                            max_len=48, seg_len=4, page_size=4,
                            chunk_size=8, precision="fp32")
    cb2.submit(np.concatenate([sys_p, u2]), max_new=5)
    ref = cb2.run(jax.random.PRNGKey(4))[0]
    assert req2.out == ref.out


def test_prefix_cache_cow_on_partial_tail(dbm_params):
    """A prompt whose shared prefix ends mid-page maps the boundary page and
    copy-on-writes it; the original page (still cache-retained) must keep
    serving the first request's suffix unchanged."""
    dbm, params = dbm_params
    rs = np.random.RandomState(5)
    sys_p = rs.randint(0, TINY.vocab_size, size=26)    # 26 = 6.5 pages of 4
    u1 = rs.randint(0, TINY.vocab_size, size=4)
    cb = _mk_prefix_batcher(dbm, params, num_slots=1)
    cb.submit(np.concatenate([sys_p, u1]), max_new=4)
    out1_first = cb.run(jax.random.PRNGKey(6))[0].out
    cows0 = cb.cow_copies
    # same FULL prompt again: full pages + the partial tail all match
    cb.submit(np.concatenate([sys_p, u1]), max_new=4)
    req2 = cb.run(jax.random.PRNGKey(6))[0]
    assert req2.shared_tokens == 30                    # whole prompt shared
    assert cb.cow_copies > cows0                       # boundary page copied
    assert req2.out == out1_first                      # same rng -> same gen
    # and the original prefix still serves a THIRD, diverging request
    u2 = (u1 + 3) % TINY.vocab_size
    cb.submit(np.concatenate([sys_p, u2]), max_new=4)
    req3 = cb.run(jax.random.PRNGKey(7))[0]
    assert req3.shared_tokens >= 24                    # full pages shared
    cb_ref = ContinuousBatcher(dbm, params, num_slots=1, max_prompt=32,
                               max_len=48, seg_len=4, page_size=4,
                               chunk_size=8, precision="fp32")
    cb_ref.submit(np.concatenate([sys_p, u2]), max_new=4)
    assert req3.out == cb_ref.run(jax.random.PRNGKey(7))[0].out


def test_prefix_cache_cow_and_sharing_int8(dbm_params):
    """Prefix sharing and boundary-page copy-on-write on an int8 pool: a
    page and its per-page scale share/copy as one unit.

    Two parity claims, chosen so each is EXACT (no tolerance):
      * identical full prompt re-served through the cache (full pages + CoW
        of the partial tail) is bit-identical to the first serve — the CoW
        copy carries the pristine prefill-time int8 bytes and scale, and
        decode appends the same fp values on top;
      * a diverging suffix behind a PAGE-ALIGNED shared prefix matches a
        from-scratch int8 serve bit-for-bit — the suffix page quantizes
        once from identical fp content in both runs. (A partial-tail CoW
        page would instead REquantize already-quantized bytes, which is
        deterministic but not byte-equal to a single-pass quantization, so
        the scratch-parity claim is made at the page boundary.)"""
    dbm, params = dbm_params
    rs = np.random.RandomState(9)
    sys_p = rs.randint(0, TINY.vocab_size, size=26)   # 6.5 pages of 4
    u1 = rs.randint(0, TINY.vocab_size, size=4)
    cb = _mk_prefix_batcher(dbm, params, num_slots=1, kv_dtype="int8")
    cb.submit(np.concatenate([sys_p, u1]), max_new=4)
    out1 = cb.run(jax.random.PRNGKey(6))[0].out
    cows0 = cb.cow_copies
    cb.submit(np.concatenate([sys_p, u1]), max_new=4)
    req2 = cb.run(jax.random.PRNGKey(6))[0]
    assert req2.shared_tokens == 30                   # whole prompt shared
    assert cb.cow_copies > cows0                      # quantized tail CoW'd
    assert req2.out == out1, (req2.out, out1)

    # page-aligned prefix: diverging suffix == unshared int8 reference
    sys_a = rs.randint(0, TINY.vocab_size, size=24)   # exactly 6 pages
    u2 = rs.randint(0, TINY.vocab_size, size=6)
    cb.submit(np.concatenate([sys_a, u1]), max_new=4)
    cb.run(jax.random.PRNGKey(8))
    cb.submit(np.concatenate([sys_a, u2]), max_new=4)
    req3 = cb.run(jax.random.PRNGKey(7))[0]
    assert req3.shared_tokens == 24
    ref = ContinuousBatcher(dbm, params, num_slots=1, max_prompt=32,
                            max_len=48, seg_len=4, page_size=4,
                            chunk_size=8, precision="fp32",
                            kv_dtype="int8")
    ref.submit(np.concatenate([sys_a, u2]), max_new=4)
    assert req3.out == ref.run(jax.random.PRNGKey(7))[0].out


def test_prefix_cache_rejects_recurrent_family():
    cfg = configs.reduced(configs.get_config("xlstm-125m"))
    dbm = make_dbm(cfg, blocks=2)
    params = dbm.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="prefix_cache"):
        ContinuousBatcher(dbm, params, num_slots=1, prefix_cache=True)


def test_prefix_cache_eviction_never_frees_matched_pages(dbm_params):
    """Admission pins the matched prefix pages BEFORE eviction runs: under
    pool pressure, evict() must not free the pages the request is about to
    map — the admission completes with the shared prefix intact and the
    output matches an unshared serve. (Regression: unpinned matched pages
    were evicted and re-allocated, crashing on a stale refcount.)"""
    dbm, params = dbm_params
    rs = np.random.RandomState(13)
    sys_p = rs.randint(0, TINY.vocab_size, size=16)    # 4 full pages of 4
    other = rs.randint(0, TINY.vocab_size, size=20)    # fills the cache too
    u2 = rs.randint(0, TINY.vocab_size, size=4)
    # pool of 10 usable pages: after request 1 (sys only -> 4 retained
    # pages, its chain LEAF included) and request 2 (other -> 5 retained),
    # only 1 page is free; admitting request 3 (sys + u2, 6 pages, 4
    # matched) needs 2 fresh pages, so evict() runs and walks the matched
    # sys chain's leaf FIRST — the pin must keep those pages alive while
    # the eviction frees other's pages instead
    cb = _mk_prefix_batcher(dbm, params, num_slots=1, max_prompt=24,
                            max_len=28, total_pages=1 + 10)
    cb.submit(sys_p, max_new=4)
    cb.run(jax.random.PRNGKey(0))
    cb.submit(other, max_new=4)
    cb.run(jax.random.PRNGKey(1))
    assert len(cb.free_pages) == 1          # pressure: eviction must run
    cb.submit(np.concatenate([sys_p, u2]), max_new=4)
    done = cb.run(jax.random.PRNGKey(2))[0]
    assert done.shared_tokens == 16         # matched prefix survived
    cb_ref = ContinuousBatcher(dbm, params, num_slots=1, max_prompt=24,
                               max_len=28, seg_len=4, page_size=4,
                               chunk_size=8, precision="fp32")
    cb_ref.submit(np.concatenate([sys_p, u2]), max_new=4)
    assert done.out == cb_ref.run(jax.random.PRNGKey(2))[0].out


# ---------------------------------------------------------------------------
# conditioned requests: aux_inputs through the batched engine
# ---------------------------------------------------------------------------

TINY_VLM = ModelConfig(name="tiny-prefill-vlm", family="vlm", n_layers=4,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab_size=32, cross_attn_every=2, n_image_tokens=4)
TINY_AUDIO = ModelConfig(name="tiny-prefill-audio", family="audio",
                         n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                         d_ff=128, vocab_size=32, n_encoder_layers=2,
                         n_audio_frames=6, rope_theta=0.0, norm="layernorm",
                         mlp="gelu", is_encoder_decoder=True)


def _conditioned_setup(family):
    """(dbm, params, prompt, auxA, auxB): a tiny conditioned model whose
    cross-attention actually moves the logits (the VLM cross gate is
    tanh(0)=0 at init, so it is opened explicitly), plus two distinct
    conditioning inputs strong enough to flip greedy argmax."""
    rs = np.random.RandomState(0)
    if family == "vlm":
        dbm = make_dbm(TINY_VLM, blocks=2)
        params = dbm.init(jax.random.PRNGKey(0))
        params["units"]["cross"]["xgate"] = 2.0 * jnp.ones_like(
            params["units"]["cross"]["xgate"])
        key, Sk = "image_embs", TINY_VLM.n_image_tokens
    else:
        dbm = make_dbm(TINY_AUDIO, blocks=2)
        params = dbm.init(jax.random.PRNGKey(0))
        key, Sk = "audio_embs", TINY_AUDIO.n_audio_frames
    prompt = rs.randint(0, 32, size=7)
    auxA = {key: 4 * rs.randn(Sk, 64).astype(np.float32)}
    auxB = {key: 4 * rs.randn(Sk, 64).astype(np.float32)}
    return dbm, params, prompt, auxA, auxB


def _dryrun_reference(dbm, params, prompt, max_new, aux, rng):
    """The single-request dry-run path: DENSE caches, conditioning through
    the model frontend, one eager serve_step per generated token — the
    numerical ground truth the batched engine must reproduce exactly."""
    model = dbm.model
    S0 = prompt.size
    cache = model.init_cache(1, S0 + max_new, jnp.float32)
    cond = model.encode_conditioning(
        params, {k: jnp.asarray(v)[None] for k, v in aux.items()})
    cache = model.set_conditioning(params, cache, cond)
    clens = jnp.full((1,), cond.shape[1], jnp.int32)
    ctx = dbm.make_ctx(params, 1, "decode", None, None, cond_lengths=clens)
    ctx.positions = None
    for t in range(S0):
        cache = dbm.commit_token(params, cache, t,
                                 jnp.asarray(prompt[t]).reshape(1, 1), ctx)
    toks = []
    for t in range(max_new):
        rng, rs_ = jax.random.split(rng)
        tok, cache = dbm.serve_step(params, cache, S0 + t, rs_,
                                    cond_lengths=clens)
        toks.append(int(tok[0]))
    return toks


@pytest.mark.slow
@pytest.mark.parametrize("family", ["vlm", "audio"])
def test_conditioned_engine_matches_dryrun(family):
    """Greedy parity for CONDITIONED requests: the static scan-fused engine
    AND the continuous batcher (prefix cache ON) must be bit-identical to
    the single-request dense dry-run path — same frontend encode, same
    cross reads, same rng stream."""
    dbm, params, prompt, auxA, _ = _conditioned_setup(family)
    ref = _dryrun_reference(dbm, params, prompt, 6, auxA,
                            jax.random.PRNGKey(7))
    out = generate(dbm, params, jnp.asarray(prompt)[None], 6,
                   rng=jax.random.PRNGKey(7), precision="fp32",
                   aux_inputs={k: jnp.asarray(v)[None]
                               for k, v in auxA.items()})
    assert [int(t) for t in np.asarray(out)[0, 7:]] == ref
    cb = ContinuousBatcher(dbm, params, num_slots=1, max_prompt=8,
                           max_len=16, seg_len=4, page_size=4, chunk_size=4,
                           precision="fp32", prefix_cache=True)
    cb.submit(prompt, 6, aux_inputs=auxA)
    assert cb.run(jax.random.PRNGKey(7))[0].out == ref


def test_conditioned_prefix_cache_differential():
    """Identical prompt TEXT under different conditioning: zero shared
    prefix pages and different greedy outputs. Identical text + identical
    conditioning fingerprint: shares pages and reproduces the output."""
    dbm, params, prompt, auxA, auxB = _conditioned_setup("vlm")
    cb = ContinuousBatcher(dbm, params, num_slots=2, max_prompt=8,
                           max_len=16, seg_len=4, page_size=4, chunk_size=4,
                           precision="fp32", prefix_cache=True)
    cb.submit(prompt, 6, aux_inputs=auxA)
    d1 = cb.run(jax.random.PRNGKey(3))[0]
    cb.submit(prompt, 6, aux_inputs=auxB)           # same text, other image
    d2 = cb.run(jax.random.PRNGKey(3))[0]
    assert d2.shared_tokens == 0                    # never shares across fp
    assert d2.out != d1.out                         # conditioning matters
    cb.submit(prompt, 6, aux_inputs=auxA)           # same text, same image
    d3 = cb.run(jax.random.PRNGKey(3))[0]
    assert d3.shared_tokens > 0                     # same fp shares
    assert d3.out == d1.out
    # unconditioned text never matches a conditioned trie
    cb.submit(prompt, 6)
    d4 = cb.run(jax.random.PRNGKey(3))[0]
    assert d4.shared_tokens == 0


def test_conditioned_and_unconditioned_slots_mix():
    """Conditioned and unconditioned requests schedule together in one
    compiled program (cond_lengths masks per slot); the run is
    deterministic and every request completes."""
    dbm, params, prompt, auxA, auxB = _conditioned_setup("vlm")
    rs = np.random.RandomState(5)
    prompts = [rs.randint(0, 32, size=rs.randint(4, 8)) for _ in range(4)]
    auxes = [auxA, None, auxB, None]

    def serve():
        cb = ContinuousBatcher(dbm, params, num_slots=2, max_prompt=8,
                               max_len=16, seg_len=4, page_size=4,
                               chunk_size=4, precision="fp32")
        for p, a in zip(prompts, auxes):
            cb.submit(p, 5, aux_inputs=a)
        return [r.out for r in cb.run(jax.random.PRNGKey(11))]

    out1 = serve()
    assert serve() == out1
    assert all(len(o) == 5 for o in out1)
    assert all(0 <= t < 32 for o in out1 for t in o)


def test_submit_rejects_aux_on_unconditioned_family(dbm_params):
    dbm, params = dbm_params
    cb = ContinuousBatcher(dbm, params, num_slots=1, max_prompt=8,
                           max_len=16, seg_len=4, page_size=4)
    with pytest.raises(ValueError, match="no aux"):
        cb.submit(np.arange(4), 2,
                  aux_inputs={"image_embs": np.zeros((4, 64), np.float32)})


def test_prefix_cache_eviction_frees_pages(dbm_params):
    """Cache-retained pages must be evictable under pool pressure: fill the
    cache with disjoint prompts, then admit one more — the batcher evicts
    rather than deadlocking."""
    dbm, params = dbm_params
    cb = _mk_prefix_batcher(dbm, params, num_slots=1, max_prompt=16,
                            max_len=24,
                            total_pages=1 + 2 * KVC.pages_for(24, 4))
    rs = np.random.RandomState(7)
    for i in range(4):                 # each run retains its prefix pages
        cb.submit(rs.randint(0, TINY.vocab_size, size=16), max_new=4)
        done = cb.run(jax.random.PRNGKey(i))
        assert len(done) == 1 and len(done[0].out) == 4
