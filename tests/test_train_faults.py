"""Fault-tolerant training: per-block anomaly guards, the TrainRunner
supervisor (pod death → round-robin degrade → re-adoption, per-block rewind,
deterministic resume), and checkpoint-generation corruption fallback.

The four mandated behaviors:
  * a NaN gradient skips ONLY that block's update — every other block's new
    state is BIT-identical to the clean step's;
  * a loss-spike streak rewinds ONLY the offending block to its last
    checkpoint generation — the other blocks keep their trained state;
  * pod death degrades the orphaned block to the round-robin path and
    training CONTINUES (then re-adopts on revival);
  * a corrupted generation (``ckpt_corrupt`` torn write) is detected by
    checksum and the PREVIOUS generation loads instead.

Everything runs on the round-robin engine path (device-count agnostic);
``benchmarks/table21_faulttrain.py`` covers shard_map parity under
``--xla_force_host_platform_device_count=8``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, tree_digest
from repro.configs import DBConfig
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import DiffusionBlocksModel
from repro.core.training import GuardConfig, make_db_train_step
from repro.data import MarkovLM, MarkovStream
from repro.launch.faults import FaultInjector
from repro.launch.trainrunner import TrainFailed, TrainRunner
from repro.parallel import BlockParallelTrainer

TINY = ModelConfig(name="tiny8", family="dense", n_layers=8, d_model=32,
                   n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64)
B = 4
BATCH, SEQ = 4, 16
QUIET = staticmethod(lambda *a: None)


@pytest.fixture(scope="module")
def dbm():
    return DiffusionBlocksModel(TINY, DBConfig(num_blocks=B,
                                               overlap_gamma=0.05))


@pytest.fixture(scope="module")
def params(dbm):
    return dbm.init(jax.random.PRNGKey(0))


def tcfg(steps=8, **kw):
    kw.setdefault("batch_size", BATCH)
    kw.setdefault("seq_len", SEQ)
    kw.setdefault("lr", 2e-3)
    kw.setdefault("warmup_steps", 2)
    kw.setdefault("log_every", 0)
    return TrainConfig(steps=steps, **kw)


def make_data_factory():
    lm = MarkovLM(vocab_size=TINY.vocab_size, seed=7)

    def make_data(cur):
        return (lm.stream(BATCH, SEQ) if cur is None
                else MarkovStream.from_cursor(cur))
    return make_data


def tokens_batch(i=0):
    lm = MarkovLM(vocab_size=TINY.vocab_size, seed=7)
    s = lm.stream(BATCH, SEQ, start_batch=i)
    return jnp.asarray(next(s))


def one_device():
    return [jax.devices()[0]]


# ---------------------------------------------------------------------------
# 1. NaN skip isolation (engine level, bitwise)
# ---------------------------------------------------------------------------
def test_nan_skips_only_that_block_bitwise(dbm, params):
    victim = 1
    tokens = tokens_batch()
    rngs = jax.random.split(jax.random.PRNGKey(42), B)

    def run(mult):
        tr = BlockParallelTrainer(dbm, tcfg(), devices=one_device())
        state = tr.init_state(params)
        state, losses, _ = tr.step(state, tokens, rngs, loss_mult=mult)
        return tr, state, np.asarray(losses)

    tr_c, clean, _ = run(None)
    mult = np.ones(B, np.float32)
    mult[victim] = np.nan
    tr_n, nand, losses = run(mult)
    assert not np.isfinite(losses[victim])
    assert not tr_n.last_ok[victim] and tr_n.anomalies[victim] == 1
    assert all(tr_n.last_ok[b] for b in range(B) if b != victim)

    tr0 = BlockParallelTrainer(dbm, tcfg(), devices=one_device())
    state0 = tr0.init_state(params)
    for b in range(B):
        s_clean, o_clean = tr_c.block_trees(clean, b)
        s_nan, o_nan = tr_n.block_trees(nand, b)
        if b == victim:
            s0, o0 = tr0.block_trees(state0, b)
            # victim: untouched — params AND moments AND step counter
            assert tree_digest(s_nan) == tree_digest(s0)
            assert tree_digest(o_nan) == tree_digest(o0)
            assert int(o_nan.step) == 0
        else:
            # everyone else: BIT-identical to the clean step
            assert tree_digest(s_nan) == tree_digest(s_clean)
            assert tree_digest(o_nan) == tree_digest(o_clean)
            assert int(o_nan.step) == 1


def test_db_guarded_step_nan_skip(dbm, params):
    guard = GuardConfig()
    init_opt, step = make_db_train_step(dbm, 0, tcfg(), guard=guard)
    opt0 = init_opt(params)
    tokens = tokens_batch()
    rng = jax.random.PRNGKey(3)
    p1, o1, e1, l1, m1 = step(params, opt0, jnp.float32(-1.0), tokens, rng)
    assert bool(m1["ok"]) and np.isfinite(float(l1))
    pn, on, en, ln, mn = step(params, opt0, jnp.float32(-1.0), tokens, rng,
                              None, float("nan"))
    assert not bool(mn["ok"])
    assert tree_digest(pn) == tree_digest(params)      # params untouched
    assert tree_digest(on) == tree_digest(opt0)        # moments + step too
    assert float(en) == -1.0                           # ewma not dragged


# ---------------------------------------------------------------------------
# 2. loss-spike / anomaly streak rewinds ONLY the offending block
# ---------------------------------------------------------------------------
def test_streak_rewind_restores_only_offending_block(dbm, tmp_path):
    """grad_nan pinned to block 1 for rewind_after consecutive batches; the
    only checkpoint generation is the initial one, so the rewind must put
    block 1 back at its INITIAL state while every other block keeps exactly
    the trained state a no-rewind control run reaches."""
    victim, batches = 1, 4
    guard = GuardConfig(rewind_after=2)
    make_data = make_data_factory()
    rng = jax.random.PRNGKey(0)

    def run(rewind_after, ckpt_dir):
        faults = FaultInjector({"grad_nan": {"at": [3, 4],
                                             "block": victim}}, seed=0)
        r = TrainRunner(dbm, tcfg(steps=batches * B), mode="block-parallel",
                        guard=GuardConfig(rewind_after=rewind_after),
                        ckpt_dir=ckpt_dir, ckpt_every=100,   # only gen 1
                        faults=faults, devices=one_device(),
                        log=lambda *a: None)
        r.train(make_data, rng)
        return r

    ctrl = run(rewind_after=100, ckpt_dir=str(tmp_path / "ctrl"))
    test = run(rewind_after=guard.rewind_after,
               ckpt_dir=str(tmp_path / "test"))
    assert test.counters["rewinds"] == 1
    assert ctrl.counters["rewinds"] == 0

    mgr = CheckpointManager(str(tmp_path / "test"))
    gen = mgr.latest_good_generation()
    for b in range(B):
        s_test, o_test = test.trainer.block_trees(test.state, b)
        s_ctrl, _ = ctrl.trainer.block_trees(ctrl.state, b)
        if b == victim:
            s_init = mgr.load_tree(gen, f"block_{b:02d}", s_test)
            assert tree_digest(s_test) == tree_digest(s_init)
        else:
            assert tree_digest(s_test) == tree_digest(s_ctrl)


# ---------------------------------------------------------------------------
# 3. pod death → degrade to round-robin orphan passes → re-adoption
# ---------------------------------------------------------------------------
def test_pod_death_degrades_and_readopts(dbm):
    batches = 5
    make_data = make_data_factory()
    faults = FaultInjector({"pod_die": {"at": [2]}}, seed=0)
    r = TrainRunner(dbm, tcfg(steps=batches * B), mode="block-parallel",
                    faults=faults, pod_restart_after=2,
                    devices=one_device(), log=lambda *a: None)
    params, hist = r.train(make_data, jax.random.PRNGKey(0))
    c = r.counters
    assert c["pod_deaths"] == 1
    assert c["degraded_batches"] == 2      # down for pod_restart_after
    assert c["readoptions"] == 1
    # training continued through the outage: every block's loss on every
    # batch is finite (the orphan advanced via the round-robin passes)
    losses = np.asarray([l for _, _, l in hist])
    assert losses.shape[0] == batches * B and np.isfinite(losses).all()
    # every block heartbeat reaches the final batch
    assert all(r.heartbeats[b] == batches - 1 for b in range(B))
    # and every block took one optimizer step per batch (orphan included —
    # its counter restarts from the rewind generation, i.e. initialization)
    opt = jax.device_get(r.state.stack_opt)
    assert [int(s) for s in opt.step] == [batches] * B


def test_db_pod_die_bounded_restart(dbm, tmp_path):
    make_data = make_data_factory()
    faults = FaultInjector({"pod_die": {"at": [5]}}, seed=0)
    r = TrainRunner(dbm, tcfg(steps=8), mode="db",
                    ckpt_dir=str(tmp_path), ckpt_every=3, faults=faults,
                    max_restarts=2, log=lambda *a: None)
    params, hist = r.train(make_data, jax.random.PRNGKey(0))
    assert r.counters["restarts"] == 1
    assert len(hist) == 8 + 1              # one step replayed after restart
    assert np.isfinite([l for _, _, l in hist]).all()

    faults = FaultInjector({"pod_die": {"every": 2}}, seed=0)
    r = TrainRunner(dbm, tcfg(steps=8), mode="db",
                    ckpt_dir=str(tmp_path / "x"), ckpt_every=3,
                    faults=faults, max_restarts=2, log=lambda *a: None)
    with pytest.raises(TrainFailed, match="budget"):
        r.train(make_data, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# 4. ckpt_corrupt → checksum detects, previous generation loads
# ---------------------------------------------------------------------------
def test_ckpt_corrupt_falls_back_to_previous_generation(tmp_path):
    tree1 = {"w": jnp.arange(8, dtype=jnp.float32)}
    faults = FaultInjector({"ckpt_corrupt": {"at": [2]}}, seed=0)
    mgr = CheckpointManager(str(tmp_path), keep=3, faults=faults)
    g1 = mgr.save({"state": tree1}, {"it": 1})
    g2 = mgr.save({"state": {"w": tree1["w"] + 1}}, {"it": 2})   # corrupted
    assert mgr.verify(g1) and not mgr.verify(g2)
    logs = []
    trees, manifest = mgr.load_latest(
        {"state": jax.tree_util.tree_map(jnp.zeros_like, tree1)},
        log=logs.append)
    assert manifest["generation"] == g1 and manifest["state"]["it"] == 1
    np.testing.assert_array_equal(np.asarray(trees["state"]["w"]),
                                  np.arange(8, dtype=np.float32))
    assert any("falling back" in s for s in logs)
    assert mgr.latest_good_generation() == g1


def test_runner_resumes_past_corrupted_generation(dbm, tmp_path):
    """End-to-end: corrupt the LAST generation of a finished run; a resume
    must fall back to the previous one and still complete."""
    make_data = make_data_factory()
    faults = FaultInjector({"ckpt_corrupt": {"at": [3]}}, seed=0)
    r = TrainRunner(dbm, tcfg(steps=8), mode="db", ckpt_dir=str(tmp_path),
                    ckpt_every=3, faults=faults, log=lambda *a: None)
    r.train(make_data, jax.random.PRNGKey(0), halt_after=7)
    mgr = CheckpointManager(str(tmp_path))
    gens = mgr.generations()
    assert not mgr.verify(gens[-1])        # the torn write landed
    assert mgr.latest_good_generation() == gens[-2]
    r2 = TrainRunner(dbm, tcfg(steps=8), mode="db", ckpt_dir=str(tmp_path),
                     ckpt_every=3, log=lambda *a: None)
    params, hist = r2.train(make_data, jax.random.PRNGKey(0), resume=True)
    assert np.isfinite([l for _, _, l in hist]).all()


# ---------------------------------------------------------------------------
# deterministic resume (round-robin path; shard_map in table21)
# ---------------------------------------------------------------------------
def test_parallel_kill_resume_bit_parity(dbm, tmp_path):
    make_data = make_data_factory()
    rng = jax.random.PRNGKey(0)

    def runner(d):
        return TrainRunner(dbm, tcfg(steps=3 * B), mode="block-parallel",
                           ckpt_dir=str(d), ckpt_every=1,
                           devices=one_device(), log=lambda *a: None)

    r_clean = runner(tmp_path / "clean")
    p_clean, _ = r_clean.train(make_data, rng)
    r_kill = runner(tmp_path / "kill")
    r_kill.train(make_data, rng, halt_after=2)
    r_res = runner(tmp_path / "kill")
    p_res, _ = r_res.train(make_data, rng, resume=True)
    assert tree_digest(p_clean) == tree_digest(p_res)
    assert (tree_digest(jax.device_get(r_clean.state.stack_opt))
            == tree_digest(jax.device_get(r_res.state.stack_opt)))
    assert (tree_digest(jax.device_get(r_clean.state.periph_opt))
            == tree_digest(jax.device_get(r_res.state.periph_opt)))
