"""Gradient-correctness suite for the custom-VJP Pallas kernels.

Every kernel's hand-written backward (interpret mode on CPU) is checked
against ``jax.grad`` of the pure-jnp oracle in ``kernels/ref.py`` — fp32 to
tight tolerance, bf16 inputs (fp32 accumulation inside the kernels) to a
loose one — including odd / padded sequence lengths and the DB-specific mask
kinds. A final end-to-end check runs ``make_db_train_step``'s loss with
``impl="kernels"`` vs the chunked reference path and compares full param
gradients (ISSUE 2 acceptance: ≤1e-4 rel-err in fp32).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.edm_loss import edm_loss
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_adaln import (fused_euler, fused_gate_residual,
                                       fused_ln_modulate)

DTYPES = [jnp.float32, jnp.bfloat16]


def gtol(dtype):
    # bf16 inputs round q/k/v and the cotangent to 8 mantissa bits, but the
    # kernels accumulate in fp32 — 4e-2 relative covers the input rounding.
    return 4e-2 if dtype == jnp.bfloat16 else 1e-5


def rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-12)


def check_grads(f_ker, f_ref, args, tol, argnums=None):
    argnums = tuple(range(len(args))) if argnums is None else argnums
    gk = jax.grad(f_ker, argnums=argnums)(*args)
    gr = jax.grad(f_ref, argnums=argnums)(*args)
    for i, (a, b) in enumerate(zip(gk, gr)):
        assert rel_err(a, b) < tol, f"arg {argnums[i]}: rel err {rel_err(a, b)}"


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,H,KV,Sq,Sk,hd", [
    (1, 2, 2, 64, 64, 32),
    (2, 4, 2, 128, 128, 32),     # GQA: dk/dv group-sum path
    (1, 4, 1, 91, 175, 32),      # MQA, odd/ragged (padding path)
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
def test_flash_attention_grads(B, H, KV, Sq, Sk, hd, dtype, causal, window):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, H, Sq, hd), dtype)
    k = jax.random.normal(k2, (B, KV, Sk, hd), dtype)
    v = jax.random.normal(k3, (B, KV, Sk, hd), dtype)

    def f_ker(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(
            q, k, v, causal=causal, window=window, block_q=64, block_k=64,
            interpret=True).astype(jnp.float32)))

    def f_ref(q, k, v):
        return jnp.sum(jnp.sin(ref.mha_reference(
            q, k, v, causal=causal, window=window).astype(jnp.float32)))

    check_grads(f_ker, f_ref, (q, k, v), gtol(dtype))


@pytest.mark.parametrize("mask_kind", ["db_concat", "two_pass"])
def test_flash_attention_db_mask_grads(mask_kind):
    """The DB training masks (App. E.4 concat / two-pass noisy stream)."""
    S, hd = 48, 16
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    Sq = 2 * S if mask_kind == "db_concat" else S
    q = jax.random.normal(k1, (1, 2, Sq, hd))
    k = jax.random.normal(k2, (1, 2, 2 * S, hd))
    v = jax.random.normal(k3, (1, 2, 2 * S, hd))
    if mask_kind == "db_concat":
        from repro.nn.attention import db_concat_mask
        mask = db_concat_mask(S)(jnp.arange(2 * S), jnp.arange(2 * S))
    else:
        from repro.models.common import two_pass_mask
        mask = two_pass_mask(S)(jnp.arange(S), jnp.arange(2 * S))

    def f_ker(q, k, v):
        return jnp.sum(flash_attention(q, k, v, mask_kind=mask_kind,
                                       mask_seq=S, block_q=32, block_k=32,
                                       interpret=True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.mha_reference_masked(q, k, v, mask) ** 2)

    np.testing.assert_allclose(float(f_ker(q, k, v)), float(f_ref(q, k, v)),
                               rtol=1e-5)
    check_grads(f_ker, f_ref, (q, k, v), 1e-5)


def test_ops_flash_attention_rejects_unsupported():
    """ops.flash_attention must NEVER silently compute wrong attention:
    untagged mask_mods and non-arange concrete positions raise."""
    from repro.kernels import ops
    from repro.nn.attention import causal_mask

    q = jax.random.normal(jax.random.PRNGKey(0), (1, 32, 2, 16))
    with pytest.raises(NotImplementedError):
        ops.flash_attention(q, q, q,
                            mask_mod=lambda qp, kp: kp[None] <= qp[:, None])
    with pytest.raises(NotImplementedError):   # packed-segment positions
        ops.flash_attention(q, q, q, mask_mod=causal_mask,
                            qpos=jnp.array([0, 1, 2, 0] * 8),
                            kpos=jnp.arange(32))
    with pytest.raises(NotImplementedError):   # wrong length
        ops.flash_attention(q, q, q, mask_mod=causal_mask,
                            qpos=jnp.arange(16), kpos=jnp.arange(32))
    out = ops.flash_attention(q, q, q, mask_mod=causal_mask,
                              qpos=jnp.arange(32), kpos=jnp.arange(32))
    assert out.shape == q.shape


def test_flash_attention_no_pallas_autodiff():
    """The VJP must be the hand-written kernels — the backward jaxpr may not
    differentiate through pallas_call (transpose of pallas_call is what
    Mosaic cannot compile)."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 32, 16))

    def f(q):
        return jnp.sum(flash_attention(q, q, q, causal=True, block_q=32,
                                       block_k=32, interpret=True))

    text = str(jax.make_jaxpr(jax.grad(f))(q))
    assert "_bwd_dq_kernel" in text and "_bwd_dkv_kernel" in text
    g = jax.grad(f)(q)
    assert np.isfinite(np.asarray(g)).all()


# ---------------------------------------------------------------------------
# fused AdaLN trio
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,S,d", [(2, 64, 128), (1, 100, 64), (3, 513, 64)])
def test_ln_modulate_grads(B, S, d, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.normal(k1, (B, S, d), dtype)
    sc = (0.1 * jax.random.normal(k2, (B, d))).astype(dtype)
    sh = (0.1 * jax.random.normal(k3, (B, d))).astype(dtype)

    def f_ker(x, sc, sh):
        return jnp.sum(jnp.cos(fused_ln_modulate(
            x, sc, sh, block_rows=64, interpret=True).astype(jnp.float32)))

    def f_ref(x, sc, sh):
        return jnp.sum(jnp.cos(
            ref.ln_modulate_reference(x, sc, sh).astype(jnp.float32)))

    check_grads(f_ker, f_ref, (x, sc, sh), gtol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,S,d", [(2, 64, 128), (1, 257, 64)])
def test_gate_residual_grads(B, S, d, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    r = jax.random.normal(k1, (B, S, d), dtype)
    br = jax.random.normal(k2, (B, S, d), dtype)
    g = (0.1 * jax.random.normal(k3, (B, d))).astype(dtype)

    def f_ker(r, br, g):
        return jnp.sum(fused_gate_residual(
            r, br, g, block_rows=64, interpret=True).astype(jnp.float32) ** 2)

    def f_ref(r, br, g):
        return jnp.sum(
            ref.gate_residual_reference(r, br, g).astype(jnp.float32) ** 2)

    check_grads(f_ker, f_ref, (r, br, g), gtol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,S,d", [(2, 64, 128), (1, 130, 64)])
def test_euler_grads(B, S, d, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(4))
    z = jax.random.normal(k1, (B, S, d), dtype)
    f = jax.random.normal(k2, (B, S, d), dtype)
    sig = jnp.linspace(0.5, 3.0, B)
    sig2 = sig * 0.3

    def f_ker(z, f):
        return jnp.sum(fused_euler(z, f, sig, sig2, 0.5, block_rows=64,
                                   interpret=True).astype(jnp.float32) ** 2)

    def f_ref(z, f):
        return jnp.sum(
            ref.euler_reference(z, f, sig, sig2, 0.5).astype(jnp.float32) ** 2)

    check_grads(f_ker, f_ref, (z, f), gtol(dtype))


def test_euler_sigma_cotangent_is_zero():
    """σ is sampled schedule data — the VJP must not propagate into it."""
    z = jax.random.normal(jax.random.PRNGKey(5), (2, 32, 16))
    sig = jnp.asarray([0.5, 1.5])

    def f(sig):
        return jnp.sum(fused_euler(z, z, sig, sig * 0.5, 0.5, block_rows=32,
                                   interpret=True))

    assert float(jnp.abs(jax.grad(f)(sig)).max()) == 0.0


# ---------------------------------------------------------------------------
# EDM loss
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,d", [(2, 64, 128), (1, 300, 64)])
def test_edm_loss_grads(B, S, d):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(6), 3)
    f = jax.random.normal(k1, (B, S, d))
    z = jax.random.normal(k2, (B, S, d))
    y = jax.random.normal(k3, (B, S, d))
    sig = jnp.linspace(0.3, 2.0, B)

    def f_ker(f, z, y):
        return edm_loss(f, z, y, sig, 0.5, interpret=True)

    def f_ref(f, z, y):
        return ref.edm_loss_reference(f, z, y, sig, 0.5)

    check_grads(f_ker, f_ref, (f, z, y), 1e-5)


# ---------------------------------------------------------------------------
# end-to-end: make_db_train_step(impl="kernels") vs the reference path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal_mode", ["concat", "two_pass"])
def test_block_loss_grads_kernels_vs_reference(causal_mode):
    from repro.configs.base import DBConfig, ModelConfig
    from repro.core import DiffusionBlocksModel
    from repro.core.training import extract_block_view

    cfg = ModelConfig(name="t", family="dense", n_layers=4, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=17)
    db = DBConfig(num_blocks=2, overlap_gamma=0.1, causal_mode=causal_mode)
    dbm = DiffusionBlocksModel(cfg, db)
    params = dbm.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 17)
    rng = jax.random.PRNGKey(2)
    view = extract_block_view(params, *dbm.ranges[0])
    size = dbm.ranges[0][1]

    def loss(v, impl):
        return dbm.block_loss(v, 0, tokens, rng, impl=impl,
                              unit_range=(0, size))[0]

    lk, gk = jax.value_and_grad(lambda v: loss(v, "kernels"))(view)
    lc, gc = jax.value_and_grad(lambda v: loss(v, "chunked"))(view)
    np.testing.assert_allclose(float(lk), float(lc), rtol=1e-5)
    errs = jax.tree_util.tree_map(rel_err, gk, gc)
    worst = max(jax.tree_util.tree_leaves(errs))
    assert worst <= 1e-4, f"worst grad rel err {worst}"


def test_block_loss_l2_kernels_vs_reference():
    """The loss="l2" branch dispatches kops.edm_loss (kernels) vs
    edm.edm_l2_loss (reference) — values and grads must agree."""
    from repro.configs.base import DBConfig, ModelConfig
    from repro.core import DiffusionBlocksModel
    from repro.core.training import extract_block_view

    cfg = ModelConfig(name="t", family="dense", n_layers=4, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=17)
    db = DBConfig(num_blocks=2, overlap_gamma=0.1, loss="l2")
    dbm = DiffusionBlocksModel(cfg, db)
    params = dbm.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 17)
    rng = jax.random.PRNGKey(2)
    view = extract_block_view(params, *dbm.ranges[0])
    size = dbm.ranges[0][1]

    def loss(v, impl):
        return dbm.block_loss(v, 0, tokens, rng, impl=impl,
                              unit_range=(0, size))[0]

    lk, gk = jax.value_and_grad(lambda v: loss(v, "kernels"))(view)
    lc, gc = jax.value_and_grad(lambda v: loss(v, "chunked"))(view)
    np.testing.assert_allclose(float(lk), float(lc), rtol=1e-5)
    worst = max(jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(rel_err, gk, gc)))
    assert worst <= 1e-4, f"worst grad rel err {worst}"


def test_kernel_coeffs_match_edm_preconditioning():
    """The kernels re-derive c_skip/c_out locally (kernels stay import-light);
    this pins them to core/edm.preconditioning so a change there cannot
    silently diverge the kernel objective."""
    from repro.core import edm
    from repro.kernels.edm_loss import _coeffs
    from repro.kernels.fused_adaln import _euler_coeffs

    sigma = jnp.asarray([0.05, 0.5, 2.0, 40.0])
    sd = 0.5
    c_skip, c_out, _, _ = edm.preconditioning(sigma, sd)
    ks, ko = _coeffs(sigma, sd)
    np.testing.assert_allclose(np.asarray(ks)[:, 0], np.asarray(c_skip),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ko)[:, 0], np.asarray(c_out),
                               rtol=1e-6)
    sigma_to = sigma * 0.3
    a, b = _euler_coeffs(sigma, sigma_to, sd)
    r = sigma_to / sigma
    np.testing.assert_allclose(np.asarray(a)[:, 0],
                               np.asarray(r + (1 - r) * c_skip), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(b)[:, 0],
                               np.asarray((1 - r) * c_out), rtol=1e-6)


def test_db_train_step_kernels_bf16_runs():
    from repro.configs.base import DBConfig, ModelConfig, TrainConfig
    from repro.core import DiffusionBlocksModel
    from repro.core.training import make_db_train_step

    cfg = ModelConfig(name="t", family="dense", n_layers=4, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=17)
    dbm = DiffusionBlocksModel(cfg, DBConfig(num_blocks=2, overlap_gamma=0.1))
    tcfg = TrainConfig(steps=2, batch_size=2, seq_len=16, log_every=0)
    params = dbm.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 17)
    io, st = make_db_train_step(dbm, 0, tcfg, impl="kernels",
                                precision="bf16")
    opt = io(params)
    p2, opt, loss, m = st(params, opt, tokens, jax.random.PRNGKey(2))
    assert np.isfinite(float(loss))
    # masters stay fp32 — mixed precision must not downcast the stored params
    assert all(x.dtype == jnp.float32
               for x in jax.tree_util.tree_leaves(p2)
               if jnp.issubdtype(x.dtype, jnp.floating))
