"""Property-test suite for the continuous-batching scheduler and the
conditioning-aware shared-prefix page cache.

Randomized admit / decode / CANCEL / retire traces drive a REAL
``ContinuousBatcher``
(real page allocator, prefix trie, copy-on-write, slot recycling, admission-
time conditioning writes) while the two heavy jitted dispatch programs are
replaced by numpy fakes with identical scheduling semantics — so hundreds of
traces run in seconds and every dispatch can assert write-safety on the host.

Invariants checked on every trace:

  * page conservation — free pages and referenced pages partition the pool
    exactly (nothing leaks, nothing is double-owned, the trash page is never
    allocated);
  * refcount accounting — ``page_refs`` equals (slot-mapped pages) +
    (prefix-trie-held pages), page for page;
  * copy-on-write safety — a dispatch only ever writes pages whose refcount
    is exactly 1 and which the writing slot owns (a write into a shared page
    would corrupt every other reader);
  * slot recycling — recycled slots never leak the previous occupant's
    conditioning: unconditioned slots always see an all-zero cross block
    (the INIT state), conditioned slots a freshly written one;
  * no cross-conditioning sharing — a request only ever shares prefix pages
    registered under ITS OWN conditioning fingerprint (identical text under
    a different image/audio input shares nothing);
  * cancellation accounting — random ``cancel(rid)`` calls between steps
    (hitting queued, admitted, and already-finished requests) keep all of
    the above true: a cancelled request ends with no pages, every
    acknowledged cancel is eventually reported exactly once, and shared
    pages only lose the cancelled slot's ref;
  * preemption (SPILL/RESTORE) accounting — random forced ``preempt(rid)``
    calls interleave with everything above: a spilled request holds ZERO
    pages while queued (its snapshot lives on the host), restores never
    outnumber spills, and a restored conditioned slot sees its cross block
    again (the admission-time conditioning check runs after restores too).

  * MIGRATE/FAILOVER accounting (disaggregation) — a second seeded driver
    runs a PrefillBatcher + decode-batcher pair through boundary-spill
    migrations, in-transit payload holds/drops, and random whole-batcher
    failover harvests (``extract_all``), in both handoff modes: per-pool
    conservation holds on separate pools, and on a ``SharedPagePool`` the
    shared refcounts decompose exactly into slot maps + trie holds +
    off-slot payload handles (queued, boundary-ready, AND in-transit) —
    so no migration seam can leak or double-own a page; every request
    still completes with its full token budget.

The seeded driver runs >= 200 traces deterministically (no hypothesis
needed); when hypothesis is installed (the dev extra — CI fast lane), the
same trace property is additionally explored by ``@given``.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAS_HYPOTHESIS = False

import jax

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core import DiffusionBlocksModel
from repro.launch.serve import ContinuousBatcher, Request, SharedPagePool
from repro.launch.workers import PrefillBatcher
from repro.nn import cache as KVC

TINY_VLM = ModelConfig(name="tiny-sched-vlm", family="vlm", n_layers=4,
                       d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                       vocab_size=32, cross_attn_every=2, n_image_tokens=4)

PSZ = 4          # page size
CHUNK = 4
MAX_PROMPT = 16
MAX_NEW = 6
MAX_LEN = MAX_PROMPT + MAX_NEW


@pytest.fixture(scope="module")
def dbm_params():
    dbm = DiffusionBlocksModel(TINY_VLM, DBConfig(num_blocks=2,
                                                  overlap_gamma=0.1))
    return dbm, dbm.init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Fake dispatch programs: numpy semantics of the jitted scan programs, plus
# host-side write-safety assertions against the batcher's page accounting.
# ---------------------------------------------------------------------------

class FakeDispatch:
    """Replaces ``eng._prefill_chunk1`` / ``eng._serve`` on one batcher."""

    def __init__(self, cb: ContinuousBatcher):
        self.cb = cb

    def _assert_writable(self, slot: int, pos: int):
        cb = self.cb
        logical = pos // PSZ
        phys = int(cb.table[slot, logical])
        assert phys != KVC.TRASH_PAGE, \
            f"slot {slot} writes pos {pos} into the trash page"
        assert cb.page_refs.get(phys, 0) == 1, \
            f"CoW violation: slot {slot} writes pos {pos} into page {phys} " \
            f"with refcount {cb.page_refs.get(phys, 0)}"
        req = cb.slot_req[slot]
        assert req is not None and req.pages[logical] == phys, \
            f"slot {slot} writes page {phys} it does not own"

    def prefill_chunk1(self, params, kv, table, lengths, prompt_buf, plens,
                       cond_lengths):
        lengths = np.array(lengths)
        plens = np.array(plens)
        adv = np.clip(plens - lengths, 0, CHUNK)
        for s in range(lengths.shape[0]):
            for p in range(int(lengths[s]), int(lengths[s] + adv[s])):
                self._assert_writable(s, p)
        return kv, lengths + adv

    def serve(self, params, kv, table, lengths, prompt_buf, plens, stop_at,
              active, rng, cond_lengths, n):
        lengths = np.array(lengths)
        stop_at, active = np.array(stop_at), np.array(active)
        plens = np.array(plens)
        B = lengths.shape[0]
        emitted = np.full((B, n), -1, np.int64)
        for t in range(n):
            act = active & (lengths < stop_at)
            for s in np.nonzero(act)[0]:
                self._assert_writable(int(s), int(lengths[s]))
                if lengths[s] >= plens[s]:
                    emitted[s, t] = 1          # dummy generated token
            lengths = lengths + act.astype(lengths.dtype)
        return kv, lengths, rng, emitted


def make_batcher(dbm, params, *, num_slots, total_pages=None,
                 prefix_cache=True, cls=ContinuousBatcher, **extra):
    cb = cls(dbm, params, num_slots=num_slots, page_size=PSZ,
             max_prompt=MAX_PROMPT, max_len=MAX_LEN,
             seg_len=3, chunk_size=CHUNK, precision="fp32",
             prefix_cache=prefix_cache,
             total_pages=total_pages, **extra)
    fake = FakeDispatch(cb)
    cb.eng = type(cb.eng).__new__(type(cb.eng))        # detached shell
    cb.eng.__dict__.update(dispatches=0, prefill_steps=0, pol=None,
                           _prefill_chunk1=fake.prefill_chunk1,
                           _serve=fake.serve)
    cb.chunked = True
    cb.chunk_size = CHUNK
    return cb


# ---------------------------------------------------------------------------
# Invariant checks (host-side, after every run and at every admission)
# ---------------------------------------------------------------------------

def walk_trie_pages(prefix):
    """Every cache-held page ref, per conditioning fingerprint root."""
    held = {}

    def walk(node):
        for child in node.children.values():
            held[child.page] = held.get(child.page, 0) + 1
            walk(child)
        for page, _ in node.tails:
            held[page] = held.get(page, 0) + 1

    for root in prefix.roots.values():
        walk(root)
    return held


def check_invariants(cb: ContinuousBatcher, *, cross_restores=False):
    """``cross_restores=True``: this batcher restores payloads spilled by
    ANOTHER batcher (migration), so restores may outnumber local
    preemptions."""
    total = cb.total_pages
    free = list(cb.free_pages)
    refs = dict(cb.page_refs)
    # -- conservation & disjointness over the pool [1, total)
    assert KVC.TRASH_PAGE not in free and KVC.TRASH_PAGE not in refs
    assert len(set(free)) == len(free), "free list holds duplicates"
    assert not (set(free) & set(refs)), "page both free and referenced"
    assert set(free) | set(refs) == set(range(1, total)), \
        "pages leaked or invented"
    assert all(r > 0 for r in refs.values())
    # -- no empty trie roots: evict prunes a root it drains, insert never
    #    leaves a fresh root with nothing registered under it (an empty
    #    root matches nothing but would accumulate forever across
    #    conditioning fingerprints)
    if cb.prefix is not None:
        for fp, root in cb.prefix.roots.items():
            assert root.children or root.tails, \
                f"empty prefix root survived for fingerprint {fp}"
    # -- refcounts decompose exactly into slot maps + trie holds
    expected = walk_trie_pages(cb.prefix) if cb.prefix is not None else {}
    for s in range(cb.num_slots):
        req = cb.slot_req[s]
        if req is None:
            continue
        assert cb.active[s]
        for p in req.pages:
            expected[p] = expected.get(p, 0) + 1
    assert refs == expected, f"refcounts {refs} != slots+trie {expected}"
    # -- slot bookkeeping
    for s in range(cb.num_slots):
        if not cb.active[s]:
            assert cb.slot_req[s] is None
            assert cb.cond_lengths[s] == 0
    # -- spilled requests wait on the HOST: no pages, snapshot + meta set
    for r in list(cb.queue):
        assert not r.pages, f"queued request {r.rid} still holds pages"
        if r.spilled is not None:
            assert r.spill_meta is not None
    if not cross_restores:
        assert cb.restores <= cb.preemptions


def check_conditioning_state(cb: ContinuousBatcher):
    """Recycled-slot hygiene: an UNCONDITIONED active slot must see the INIT
    (all-zero) cross block — never a previous occupant's image."""
    ck = np.asarray(cb.kv["cross"]["k"], np.float32)
    cv = np.asarray(cb.kv["cross"]["v"], np.float32)
    for s in range(cb.num_slots):
        if cb.active[s] and cb.cond_lengths[s] == 0:
            assert np.all(ck[:, s] == 0) and np.all(cv[:, s] == 0), \
                f"slot {s}: unconditioned but cross block is non-zero"
        if cb.active[s] and cb.cond_lengths[s] > 0:
            assert np.any(ck[:, s, :cb.cond_lengths[s]] != 0), \
                f"slot {s}: conditioned but cross block is empty"


# ---------------------------------------------------------------------------
# Trace driver
# ---------------------------------------------------------------------------

def run_trace(dbm, params, seed: int, **extra):
    rs = np.random.RandomState(seed)
    num_slots = int(rs.randint(1, 4))
    # modest pool so eviction paths run; floor covers one max request + CoW
    pps = KVC.pages_for(MAX_LEN, PSZ)
    total_pages = 1 + int(rs.randint(pps + 2, num_slots * pps + 4))
    cb = make_batcher(dbm, params, num_slots=num_slots,
                      total_pages=total_pages, **extra)

    # conditioning pool: collisions on purpose (same fp shares, different
    # fp must not), plus unconditioned requests
    cond_pool = [None,
                 rs.randn(4, TINY_VLM.d_model).astype(np.float32),
                 rs.randn(4, TINY_VLM.d_model).astype(np.float32)]
    # prompt pool: heavy shared prefixes
    prefixes = [rs.randint(0, 32, size=int(rs.randint(4, 13)))
                for _ in range(3)]

    orig_admit = cb._admit

    def admit_checked():
        n = orig_admit()
        if n:
            check_invariants(cb)
            check_conditioning_state(cb)
        return n

    cb._admit = admit_checked

    submitted = []              # (prompt, cond_idx, req)
    acked_cancels = set()       # rids whose cancel() returned True
    reported = []               # finished/cancelled requests, in order
    rng = jax.random.PRNGKey(seed)
    pool_errors = 0
    for _ in range(int(rs.randint(1, 4))):      # submission waves
        for _ in range(int(rs.randint(1, 5))):
            pre = prefixes[rs.randint(len(prefixes))]
            tail = rs.randint(0, 32, size=int(rs.randint(0, 5)))
            prompt = np.concatenate([pre, tail])[:MAX_PROMPT]
            ci = int(rs.randint(len(cond_pool)))
            aux = (None if cond_pool[ci] is None
                   else {"image_embs": cond_pool[ci]})
            max_new = int(rs.randint(1, MAX_NEW + 1))
            rid = cb.submit(prompt, max_new, aux_inputs=aux)
            req = cb.queue[-1]
            assert req.rid == rid
            submitted.append((prompt, ci, req))
        # drain this wave step by step, firing random cancels in between —
        # victims may be queued, admitted, finished, or already cancelled
        while cb.has_work():
            if submitted and rs.rand() < 0.25:
                victim = submitted[int(rs.randint(len(submitted)))][2]
                if cb.cancel(victim.rid):
                    acked_cancels.add(victim.rid)
            if submitted and rs.rand() < 0.2:
                # forced preemption: victims may be queued, active (spill +
                # later restore), finished, or cancelled — all must be safe
                cb.preempt(submitted[int(rs.randint(len(submitted)))][2].rid)
            try:
                rng, fin = cb.step(rng)
            except RuntimeError as e:           # pool too small to admit
                assert "page pool" in str(e)
                cb.queue.clear()                # drop the stuck wave
                pool_errors += 1
                fin = []
            reported.extend(fin)
            check_invariants(cb)
        check_invariants(cb)

    # -- cancellation accounting (a RuntimeError step discards its finished
    # list and queue.clear() can drop an acked-but-unapplied victim, so the
    # exact-counting claims hold only on traces without pool errors)
    by_rid = {}
    for r in reported:
        assert r.rid not in by_rid, f"request {r.rid} reported twice"
        by_rid[r.rid] = r
    assert cb.cancelled_count <= len(acked_cancels)
    for _, _, req in submitted:
        if req.cancelled:
            assert req.rid in acked_cancels, \
                f"request {req.rid} cancelled without an acked cancel()"
            assert not req.pages, \
                f"cancelled request {req.rid} still holds pages"
    if not pool_errors:
        assert cb.cancelled_count == len(acked_cancels)
        for rid in acked_cancels:       # every acked cancel reported, marked
            assert by_rid[rid].cancelled
        for _, _, req in submitted:
            if req.rid in by_rid and req.rid not in acked_cancels:
                assert not by_rid[req.rid].cancelled

    # -- no cross-conditioning prefix sharing: a request may share at most
    # the longest common prefix it has with OTHER requests under the SAME
    # conditioning fingerprint; with no same-fp sibling it shares nothing.
    def common_prefix(a, b):
        m = min(a.size, b.size)
        neq = np.nonzero(a[:m] != b[:m])[0]
        return int(neq[0]) if neq.size else m

    for i, (prompt, ci, req) in enumerate(submitted):
        if req.shared_tokens == 0:
            continue
        same_fp_cp = [common_prefix(prompt, p2)
                      for j, (p2, cj, _) in enumerate(submitted)
                      if j != i and cj == ci]
        bound = max(same_fp_cp, default=0)
        assert req.shared_tokens <= bound, \
            f"request shared {req.shared_tokens} tokens but its longest " \
            f"same-conditioning common prefix is {bound} (cross-" \
            f"conditioning sharing)"
    return cb


# ---------------------------------------------------------------------------
# Seeded driver: >= 200 deterministic randomized traces (no hypothesis)
# ---------------------------------------------------------------------------

N_TRACES = 200


def test_scheduler_traces_seeded(dbm_params):
    dbm, params = dbm_params
    for seed in range(N_TRACES):
        run_trace(dbm, params, seed)


def test_scheduler_traces_seeded_int8(dbm_params):
    """A slice of the same traces on an int8-quantized pool. The fake
    dispatches skip KV math, but the REAL quantized pool still backs every
    scheduler path the traces drive: spill snapshots must carry int8 pages
    plus their fp32 per-page scales, restores must scatter both back, and
    copy-on-write must move the scales with the page bytes — under the same
    conservation / refcount / empty-root invariants as the dense pool."""
    dbm, params = dbm_params
    for seed in range(25):
        run_trace(dbm, params, seed, kv_dtype="int8")


def test_prefix_cache_insert_registers_nothing_leaves_no_root():
    """An insert that registers nothing (sub-page prompt with no page to
    offer as a tail candidate) must not leave an empty root behind — an
    empty root matches nothing, survives need-bounded eviction sweeps, and
    would accumulate forever across conditioning fingerprints."""
    pc = KVC.PrefixPageCache(page_size=4)
    refs = {}
    pc.insert(np.arange(2), [], refs, cond_fp=9)
    assert 9 not in pc.roots and not refs
    # a tail-only root IS kept — and evicting it prunes the root again
    pc.insert(np.arange(3), [5], refs, cond_fp=10)
    assert pc.roots[10].tails and refs == {5: 1}
    free = []
    assert pc.evict(refs, free, need=1) == 1
    assert 10 not in pc.roots and not refs and free == [5]


def test_retire_returns_all_pages_without_prefix_cache(dbm_params):
    """Without the prefix cache no refs survive retirement: every page goes
    back to the free list after each trace drains."""
    dbm, params = dbm_params
    rs = np.random.RandomState(42)
    cb = make_batcher(dbm, params, num_slots=2, prefix_cache=False)
    for _ in range(6):
        cb.submit(rs.randint(0, 32, size=int(rs.randint(3, MAX_PROMPT))),
                  int(rs.randint(1, MAX_NEW)),
                  aux_inputs={"image_embs":
                              rs.randn(4, TINY_VLM.d_model).astype(np.float32)})
    cb.run(jax.random.PRNGKey(0))
    assert not cb.page_refs
    assert sorted(cb.free_pages) == list(range(1, cb.total_pages))
    assert not any(cb.active)


def test_prefix_cache_fingerprint_roots():
    """One trie root per conditioning fingerprint: lookups are pure (no
    roots created), eviction drains roots in insertion order and prunes
    empty ones, and chains under different fingerprints never alias."""
    pc = KVC.PrefixPageCache(page_size=4)
    refs, free = {}, []
    tok = np.arange(16)
    pc.insert(tok, [1, 2, 3, 4], refs, cond_fp=111)
    pc.insert(tok, [5, 6, 7, 8], refs, cond_fp=222)
    assert pc.match(tok, 111).pages == [1, 2, 3, 4]
    assert pc.match(tok, 222).pages == [5, 6, 7, 8]
    assert pc.match(tok, 333).pages == [] and 333 not in pc.roots
    assert refs == {p: 1 for p in range(1, 9)}
    assert pc.evict(refs, free, need=4) == 4
    assert sorted(free) == [1, 2, 3, 4]          # first root drained ...
    assert 111 not in pc.roots and 222 in pc.roots
    assert pc.match(tok, 222).pages == [5, 6, 7, 8]   # ... second intact
    pc.evict(refs, free, need=8)
    assert not refs and not pc.roots
    # partial tails live under their fingerprint too
    tok2 = np.arange(10)
    pc.insert(tok2, [1, 2, 3], {}, cond_fp=7)
    m = pc.match(tok2, 7)
    assert (m.pages, m.n_tokens, m.tail_tokens) == ([1, 2, 3], 10, 2)
    assert pc.match(tok2, 8).n_tokens == 0


def test_fingerprint_distinguishes_content():
    a = {"image_embs": np.ones((4, 8), np.float32)}
    b = {"image_embs": np.zeros((4, 8), np.float32)}
    c = {"image_embs": np.ones((4, 8), np.float32)}
    assert KVC.conditioning_fingerprint(a) == KVC.conditioning_fingerprint(c)
    assert KVC.conditioning_fingerprint(a) != KVC.conditioning_fingerprint(b)
    assert KVC.conditioning_fingerprint(None) == 0
    assert KVC.conditioning_fingerprint({}) == 0
    # shape-sensitive even when bytes agree
    d = {"image_embs": np.ones((8, 4), np.float32)}
    assert KVC.conditioning_fingerprint(a) != KVC.conditioning_fingerprint(d)


# ---------------------------------------------------------------------------
# MIGRATE / FAILOVER traces: PrefillBatcher + decode batcher with the
# migration seams driven by the test (no router threads) — leak/refcount
# invariants across boundary spills, in-transit payloads, payload drops,
# and whole-batcher failover harvests, in both handoff modes.
# ---------------------------------------------------------------------------

def check_shared_conservation(shared, batchers, in_transit):
    """SharedPagePool conservation: free and referenced pages partition the
    pool, and every ref is owned by exactly one of: a slot map, a prefix
    trie hold, or an off-slot payload handle (queued / boundary-ready /
    in-transit ``handoff_pages``)."""
    free = list(shared.free_pages)
    refs = dict(shared.page_refs)
    assert KVC.TRASH_PAGE not in free and KVC.TRASH_PAGE not in refs
    assert len(set(free)) == len(free), "shared free list holds duplicates"
    assert not (set(free) & set(refs)), "page both free and referenced"
    assert set(free) | set(refs) == set(range(1, shared.total_pages)), \
        "shared pages leaked or invented"
    expected = {}

    def add(pages, k=1):
        for p in pages:
            expected[p] = expected.get(p, 0) + k

    off_slot = list(in_transit)
    for cb in batchers:
        if cb.prefix is not None:
            for p, c in walk_trie_pages(cb.prefix).items():
                expected[p] = expected.get(p, 0) + c
        for s in range(cb.num_slots):
            req = cb.slot_req[s]
            if req is not None:
                add(req.pages)
        off_slot.extend(list(cb.queue))
        off_slot.extend(list(getattr(cb, "ready", ())))
    for r in off_slot:
        add(r.handoff_pages or [])
        assert not r.pages, f"off-slot request {r.rid} holds mapped pages"
    assert refs == expected, \
        f"shared refcounts {refs} != slots+trie+payloads {expected}"


def run_migration_trace(dbm, params, seed: int):
    rs = np.random.RandomState(seed)
    handoff = ("copy", "pages")[int(rs.randint(2))]
    num_slots = int(rs.randint(1, 3))
    pps = KVC.pages_for(MAX_LEN, PSZ)
    extra, shared = {}, None
    use_pc = bool(rs.rand() < 0.5)
    if handoff == "pages":
        # prefix-trie refs live in the SHARED pool but only their owning
        # batcher can evict them, so when the cache is on the pool carries
        # enough slack that the decode side can never starve behind them
        slack = (5 * KVC.pages_for(MAX_LEN, PSZ) + 4 if use_pc
                 else int(rs.randint(0, 5)))
        shared = SharedPagePool(1 + 2 * num_slots * pps + slack)
        extra["shared_pool"] = shared
    pre = make_batcher(dbm, params, num_slots=num_slots,
                       prefix_cache=use_pc,
                       cls=PrefillBatcher, handoff=handoff, **extra)
    dec = make_batcher(dbm, params, num_slots=num_slots,
                       prefix_cache=False, **extra)

    cond = rs.randn(4, TINY_VLM.d_model).astype(np.float32)
    meta = {}                   # rid -> (orig prompt, max_new)
    delivered = {}              # rid -> tokens already out of a dead inner
    finished = {}               # rid -> total tokens at terminal finish
    transit = []                # payloads the "router" holds in flight
    rng = jax.random.PRNGKey(seed)
    events = {"migrate": 0, "drop": 0, "failover": 0, "re_prefill": 0}

    def checks():
        if shared is None:
            check_invariants(pre, cross_restores=True)
            check_invariants(dec, cross_restores=True)
        else:
            check_shared_conservation(shared, (pre, dec), transit)

    def finish(req):
        assert req.rid not in finished, f"request {req.rid} finished twice"
        finished[req.rid] = delivered.get(req.rid, 0) + len(req.out)

    def re_prefill(r):
        """Payload lost: rebuild from prompt + delivered tokens (router's
        last-resort path)."""
        events["re_prefill"] += 1
        delivered[r.rid] = delivered.get(r.rid, 0) + len(r.out)
        prompt, max_new = meta[r.rid]
        remaining = max_new - delivered[r.rid]
        if remaining <= 0:
            finished.setdefault(r.rid, delivered[r.rid])
            return
        full = (np.concatenate([prompt,
                                np.full(delivered[r.rid], 1, np.int32)])
                if delivered[r.rid] else prompt)
        nr = Request(r.rid, full, remaining, aux_inputs=r.aux_inputs,
                     cond_fp=r.cond_fp)
        pre.submit_request(nr)

    def route_harvested(r):
        if r.spilled is not None and r.spill_meta["length"] >= len(r.prompt):
            transit.append(r)            # decode-ready: re-migrate
        elif r.spilled is not None:
            pre.submit_request(r)        # mid-prefill: back to prefill
        else:
            re_prefill(r)                # KV died with the worker

    rid = 0
    for _ in range(400):
        if rid < 5 and rs.rand() < 0.5:
            prompt = rs.randint(0, 32, size=int(rs.randint(3, MAX_PROMPT)))
            max_new = int(rs.randint(1, MAX_NEW + 1))
            aux = ({"image_embs": cond} if rs.rand() < 0.4 else None)
            r = Request(rid, np.asarray(prompt, np.int32), max_new,
                        aux_inputs=aux,
                        cond_fp=KVC.conditioning_fingerprint(aux))
            meta[rid] = (np.asarray(prompt, np.int32), max_new)
            pre.submit_request(r)
            rid += 1
        if pre.has_work():
            rng, fin = pre.step(rng, strict=False)
            for r in fin:
                finish(r)                # cancelled/errored only
        for r in pre.drain_ready():
            transit.append(r)
        # the router's send: deliver, drop (lost in transit), or hold
        still = []
        for r in transit:
            u = rs.rand()
            if u < 0.5:
                events["migrate"] += 1
                dec.submit_request(r)
            elif u < 0.65:
                events["drop"] += 1
                pre._drop_payload(r)
                re_prefill(r)
            else:
                still.append(r)
        transit[:] = still
        if dec.has_work():
            rng, fin = dec.step(rng, strict=False)
            for r in fin:
                finish(r)
        if rs.rand() < 0.06:             # worker death: harvest + re-route
            victim = (pre, dec)[int(rs.randint(2))]
            events["failover"] += 1
            if victim is pre:
                for r in pre.drain_ready():
                    transit.append(r)
            for r in victim.extract_all(detach=(handoff == "pages")):
                route_harvested(r)
        checks()
        if (rid >= 5 and not pre.has_work() and not dec.has_work()
                and not transit and len(finished) == rid):
            break
    else:
        raise AssertionError(
            f"trace did not drain: finished {len(finished)}/{rid}, "
            f"transit {len(transit)}, events {events}")

    for r_id, (_, max_new) in meta.items():
        assert finished[r_id] == max_new, \
            (f"request {r_id} finished with {finished[r_id]} of "
             f"{max_new} tokens", events)
    checks()
    # drained pools hold nothing beyond prefix-trie refs
    if shared is not None:
        trie = {}
        for cb in (pre, dec):
            if cb.prefix is not None:
                for p, c in walk_trie_pages(cb.prefix).items():
                    trie[p] = trie.get(p, 0) + c
        assert dict(shared.page_refs) == trie
    return events


N_MIGRATION_TRACES = 40


def test_migration_failover_traces_seeded(dbm_params):
    dbm, params = dbm_params
    totals = {"migrate": 0, "drop": 0, "failover": 0, "re_prefill": 0}
    for seed in range(N_MIGRATION_TRACES):
        ev = run_migration_trace(dbm, params, seed)
        for k in totals:
            totals[k] += ev[k]
    # the sweep must actually exercise every seam
    assert all(v > 0 for v in totals.values()), totals


# ---------------------------------------------------------------------------
# Hypothesis exploration of the same property (dev extra / CI)
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(min_value=N_TRACES, max_value=10 * N_TRACES))
    def test_scheduler_traces_hypothesis(dbm_params, seed):
        dbm, params = dbm_params
        run_trace(dbm, params, seed)
