"""HTTP/SSE serving frontend tests: stream reassembly is bit-identical to
the direct engine paths (static ``generate`` and a direct ``step()`` loop),
mid-stream cancellation frees pages (allocator stats), slow-consumer
backpressure pauses the slot without corrupting output, concurrent ragged
clients, request validation, and drain-on-shutdown semantics.

Bit-parity discipline: greedy decoding starts each denoise from rng-drawn
noise of shape ``(num_slots, 1, d)``, so outputs depend on the rng stream
AND the slot geometry. Parity tests therefore use ``num_slots=1`` servers,
ONE request in flight at a time, and pass the SAME ``PRNGKey`` to the
server's engine thread and the reference path (idle engine steps consume no
rng, so sequential requests stay deterministic). Tests with concurrent
clients assert completeness and accounting, not token equality.
"""
import asyncio

import jax
import numpy as np
import pytest

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core import DiffusionBlocksModel
from repro.launch.serve import ContinuousBatcher, generate
from repro.launch.server import (EngineRunner, InferenceServer, TokenStream,
                                 request_json, stream_generate)

TINY = ModelConfig(name="tiny-server", family="dense", n_layers=4,
                   d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                   vocab_size=32)

# one static engine config for the whole module (memoized on the dbm):
# fp32 so host/float comparisons are exact, small pages/segments so the
# scheduler actually schedules
CB_KW = dict(max_prompt=12, max_len=24, seg_len=3, page_size=4,
             chunk_size=4, precision="fp32")
GEN_KW = dict(precision="fp32", page_size=4, chunk_size=4)


@pytest.fixture(scope="module")
def dbm_params():
    dbm = DiffusionBlocksModel(TINY, DBConfig(num_blocks=2,
                                              overlap_gamma=0.1))
    return dbm, dbm.init(jax.random.PRNGKey(0))


def make_prompts(seed, n, lo=3, hi=10):
    rs = np.random.RandomState(seed)
    return [rs.randint(0, TINY.vocab_size, size=rs.randint(lo, hi))
            for _ in range(n)]


async def serve_env(dbm, params, *, num_slots=1, rng_seed=7,
                    queue_cap=256, **kw):
    cb = ContinuousBatcher(dbm, params, num_slots=num_slots,
                           **{**CB_KW, **kw})
    server = InferenceServer(cb, queue_cap=queue_cap,
                             rng=jax.random.PRNGKey(rng_seed))
    await server.start()
    return cb, server


def direct_sequential(dbm, params, prompts, max_new, rng_seed, *,
                      num_slots=1):
    """Reference: the same requests through a direct ``step()`` loop, one at
    a time, threading ONE rng — exactly what a sequential-client server
    does."""
    cb = ContinuousBatcher(dbm, params, num_slots=num_slots, **CB_KW)
    rng = jax.random.PRNGKey(rng_seed)
    outs = {}
    for p in prompts:
        rid = cb.submit(p, max_new)
        while cb.has_work():
            rng, fin = cb.step(rng)
            outs.update({r.rid: list(r.out) for r in fin})
        assert rid in outs
    return [outs[i] for i in sorted(outs)]


# ---------------------------------------------------------------------------
# Bit-parity: SSE reassembly == static generate == non-streaming response
# ---------------------------------------------------------------------------

def test_sse_stream_matches_direct_generate(dbm_params):
    """ONE streamed request on a single-slot server reassembles to exactly
    the static ``generate()`` output for the same PRNGKey."""
    dbm, params = dbm_params
    prompt = make_prompts(0, 1)[0]
    max_new = 7

    async def main():
        cb, server = await serve_env(dbm, params, rng_seed=7)
        try:
            return await stream_generate("127.0.0.1", server.port, prompt,
                                         max_new)
        finally:
            await server.aclose()

    r = asyncio.run(main())
    assert r["status"] == 200 and r["final"]["cancelled"] is False
    direct = np.asarray(generate(dbm, params, np.asarray(prompt)[None],
                                 max_new, rng=jax.random.PRNGKey(7),
                                 **GEN_KW))[0, len(prompt):]
    assert r["ids"] == [int(t) for t in direct]
    assert r["final"]["ids"] == r["ids"] and r["final"]["n"] == max_new
    # streamed per-segment: more than one token event for 7 tokens at seg 3
    assert len(r["token_counts"]) >= 2
    assert "ttft_ms" in r["final"] and r["final"]["ttft_ms"] >= 0


def test_sse_sequential_matches_direct_step_loop(dbm_params):
    """Ragged sequential streams reassemble bit-identically to the direct
    batcher step loop threading the same rng."""
    dbm, params = dbm_params
    prompts = make_prompts(1, 4)
    max_new = 6

    async def main():
        cb, server = await serve_env(dbm, params, rng_seed=11)
        try:
            out = []
            for p in prompts:           # sequential: one in flight at a time
                r = await stream_generate("127.0.0.1", server.port, p,
                                          max_new)
                assert r["status"] == 200
                out.append(r["ids"])
            return out
        finally:
            await server.aclose()

    got = asyncio.run(main())
    want = direct_sequential(dbm, params, prompts, max_new, 11)
    assert got == want


def test_nonstreaming_response_matches_sse(dbm_params):
    """``"stream": false`` returns one JSON body whose ids equal the SSE
    reassembly for the same seed (two fresh servers, same rng)."""
    dbm, params = dbm_params
    prompt = make_prompts(2, 1)[0]

    async def once(stream):
        cb, server = await serve_env(dbm, params, rng_seed=13)
        try:
            if stream:
                r = await stream_generate("127.0.0.1", server.port, prompt, 6)
                assert r["status"] == 200
                return r["ids"]
            code, obj = await request_json(
                "127.0.0.1", server.port, "POST", "/v1/generate",
                {"prompt": [int(t) for t in prompt], "max_new": 6,
                 "stream": False})
            assert code == 200 and obj["cancelled"] is False
            return obj["ids"]
        finally:
            await server.aclose()

    assert asyncio.run(once(True)) == asyncio.run(once(False))


# ---------------------------------------------------------------------------
# Cancellation
# ---------------------------------------------------------------------------

def test_cancellation_frees_pages(dbm_params):
    """Mid-stream POST /v1/cancel retires the slot: the stream ends early
    with ``cancelled: true`` and every page returns to the pool."""
    dbm, params = dbm_params
    prompt = make_prompts(3, 1)[0]

    async def main():
        cb, server = await serve_env(dbm, params, num_slots=2)
        try:
            r = await stream_generate("127.0.0.1", server.port, prompt, 18,
                                      cancel_after=2)
            code, health = await request_json("127.0.0.1", server.port,
                                              "GET", "/v1/health")
            return cb, r, health
        finally:
            await server.aclose()

    cb, r, health = asyncio.run(main())
    assert r["final"]["cancelled"] is True
    assert 2 <= len(r["ids"]) < 18
    assert r["final"]["ids"] == r["ids"]
    assert len(cb.free_pages) == cb.total_pages - 1     # allocator stats
    assert not cb.page_refs and not cb.active.any()
    assert health["cancelled"] == 1 and health["active_slots"] == 0


def test_cancel_unknown_rid_reports_false(dbm_params):
    dbm, params = dbm_params

    async def main():
        cb, server = await serve_env(dbm, params)
        try:
            code, obj = await request_json("127.0.0.1", server.port, "POST",
                                           "/v1/cancel/999")
            assert code == 200 and obj["cancelled"] is False
            code, obj = await request_json("127.0.0.1", server.port, "POST",
                                           "/v1/cancel/bogus")
            assert code == 400
        finally:
            await server.aclose()

    asyncio.run(main())


# ---------------------------------------------------------------------------
# Backpressure
# ---------------------------------------------------------------------------

def test_slow_consumer_backpressure_pauses_without_corruption(dbm_params):
    """A consumer slower than the engine trips the bounded bridge queue: the
    slot is PAUSED (engine stops decoding it) until the consumer drains, yet
    the reassembled stream is still bit-identical to static ``generate`` —
    paused steps dispatch nothing, so no rng is consumed while waiting.

    Drives the production bridge (``EngineRunner`` + ``TokenStream`` +
    ``pause``/``resume``) with a deliberately slow ``next_batch`` consumer —
    over a socket the server drains the bridge into the OS send buffer, so
    only a stalled bridge consumer exercises this path deterministically."""
    dbm, params = dbm_params
    prompt = make_prompts(4, 1)[0]
    max_new = 15

    async def main():
        cb = ContinuousBatcher(dbm, params, num_slots=1, **CB_KW)
        runner = EngineRunner(cb, rng=jax.random.PRNGKey(17))
        runner.start()
        pauses = []

        def on_pause(r):
            pauses.append(r)
            cb.pause(r)

        rid = cb.submit(np.asarray(prompt, np.int32), max_new)
        stream = TokenStream(
            asyncio.get_running_loop(), rid, cap=4, on_pause=on_pause,
            on_resume=lambda r: (cb.resume(r), runner.wake()))
        runner.attach(rid, stream)
        ids, done = [], False
        while not done:
            toks, done = await stream.next_batch()
            ids.extend(toks)
            await asyncio.sleep(0.1)        # slow consumer
        runner.stop(timeout=10)
        return ids, pauses, stream.pauses

    ids, pauses, n_pauses = asyncio.run(main())
    assert len(ids) == max_new
    assert pauses and n_pauses >= 1         # backpressure actually engaged
    direct = np.asarray(generate(dbm, params, np.asarray(prompt)[None],
                                 max_new, rng=jax.random.PRNGKey(17),
                                 **GEN_KW))[0, len(prompt):]
    assert ids == [int(t) for t in direct]


# ---------------------------------------------------------------------------
# Concurrency, validation, drain
# ---------------------------------------------------------------------------

def test_concurrent_ragged_clients_complete(dbm_params):
    """More ragged clients than slots, all streaming at once: every request
    completes with its full token budget, ids are unique, and the page pool
    is whole afterwards. (No token-equality assertion: concurrent admission
    interleaves segments, which legitimately changes the rng stream.)"""
    dbm, params = dbm_params
    prompts = make_prompts(5, 5)
    news = [4, 7, 3, 6, 5]

    async def main():
        cb, server = await serve_env(dbm, params, num_slots=2)
        try:
            rets = await asyncio.gather(*[
                stream_generate("127.0.0.1", server.port, p, n)
                for p, n in zip(prompts, news)])
            return cb, rets, server.stats()
        finally:
            await server.aclose()

    cb, rets, stats = asyncio.run(main())
    assert [r["status"] for r in rets] == [200] * 5
    for r, n in zip(rets, news):
        assert len(r["ids"]) == n and r["final"]["cancelled"] is False
        assert all(0 <= t < TINY.vocab_size for t in r["ids"])
    assert len({r["request_id"] for r in rets}) == 5
    assert stats["served"] == 5 and stats["active_slots"] == 0
    assert len(cb.free_pages) == cb.total_pages - 1


def test_request_validation(dbm_params):
    dbm, params = dbm_params

    async def post(server, payload):
        return await request_json("127.0.0.1", server.port, "POST",
                                  "/v1/generate", payload)

    async def main():
        cb, server = await serve_env(dbm, params)
        try:
            bad = [
                {"prompt": [], "max_new": 4},                 # empty
                {"prompt": [1, "a"], "max_new": 4},           # non-int
                {"prompt": [1, 99], "max_new": 4},            # out of vocab
                {"prompt": [1] * 13, "max_new": 4},           # > max_prompt
                {"prompt": [1, 2], "max_new": 0},             # bad max_new
                {"prompt": [1, 2], "max_new": 23},            # > max_len
                {"prompt": [1, 2], "max_new": 4,
                 "temperature": 0.9},                         # engine-static
                {"prompt": [1, 2], "max_new": 4, "top_k": 5},
                {"prompt": [1, 2], "max_new": 4, "aux": "nope"},
                [1, 2, 3],                                    # not an object
            ]
            for payload in bad:
                code, obj = await post(server, payload)
                assert code == 400 and "error" in obj, payload
            code, _ = await request_json("127.0.0.1", server.port, "GET",
                                         "/v1/nope")
            assert code == 404
            # matching engine-static sampler values are accepted
            code, obj = await post(server, {"prompt": [1, 2], "max_new": 2,
                                            "temperature": 0.0, "top_k": 0,
                                            "stream": False})
            assert code == 200
        finally:
            await server.aclose()

    asyncio.run(main())


def test_drain_completes_in_flight_and_rejects_new(dbm_params):
    """``drain()`` lets in-flight streams run to completion (full token
    budgets delivered) while new generate calls get 503."""
    dbm, params = dbm_params
    prompts = make_prompts(6, 2)

    async def main():
        cb, server = await serve_env(dbm, params, num_slots=2)
        tasks = [asyncio.ensure_future(
            stream_generate("127.0.0.1", server.port, p, 10))
            for p in prompts]
        # wait until both requests are actually inside the engine
        for _ in range(200):
            _, h = await request_json("127.0.0.1", server.port, "GET",
                                      "/v1/health")
            if h["active_slots"] + h["queued"] >= 2:
                break
            await asyncio.sleep(0.02)
        await server.drain()
        rets = await asyncio.gather(*tasks)
        code, obj = await request_json(
            "127.0.0.1", server.port, "POST", "/v1/generate",
            {"prompt": [1, 2], "max_new": 2})
        await server.aclose()
        return cb, rets, code, obj, server.stats()

    cb, rets, code, obj, stats = asyncio.run(main())
    for r in rets:
        assert r["status"] == 200 and len(r["ids"]) == 10
        assert r["final"]["cancelled"] is False
    assert code == 503 and "drain" in obj["error"]
    assert stats["draining"] and stats["served"] == 2
    assert len(cb.free_pages) == cb.total_pages - 1


def test_drain_mid_chunked_prefill_no_page_leak(dbm_params):
    """``drain()`` fired while a request is still CHUNK-PREFILLING (a
    max-length prompt takes 3 chunk dispatches) completes that request in
    full, leaks no pages, and rejects new work with 503 + Retry-After."""
    dbm, params = dbm_params
    prompt = np.arange(12, dtype=np.int32) % TINY.vocab_size   # 3 chunks

    async def main():
        cb, server = await serve_env(dbm, params, num_slots=1)
        task = asyncio.ensure_future(
            stream_generate("127.0.0.1", server.port, prompt, 10))
        for _ in range(200):            # catch the request inside the engine
            _, h = await request_json("127.0.0.1", server.port, "GET",
                                      "/v1/health")
            if h["active_slots"] >= 1:
                break
            await asyncio.sleep(0.005)
        await server.drain()            # prefill (3 chunks) still running
        r = await task
        code, obj, hdrs = await request_json(
            "127.0.0.1", server.port, "POST", "/v1/generate",
            {"prompt": [1, 2], "max_new": 2}, return_headers=True)
        await server.aclose()
        return cb, r, code, obj, hdrs

    cb, r, code, obj, hdrs = asyncio.run(main())
    assert r["status"] == 200 and len(r["ids"]) == 10
    assert code == 503 and "retry-after" in hdrs
    assert float(hdrs["retry-after"]) > 0
    assert len(cb.free_pages) == cb.total_pages - 1
    assert not cb.page_refs and not cb.active.any()


# ---------------------------------------------------------------------------
# Admission control over HTTP + extended health
# ---------------------------------------------------------------------------

def test_admission_shed_429_with_retry_after(dbm_params):
    """Queue-depth overload sheds a standard request with 429 + Retry-After
    while an interactive request is still admitted (class-aware backlog);
    the shed shows up in /v1/health."""
    from repro.launch.faults import FaultInjector

    dbm, params = dbm_params
    prompts = make_prompts(7, 2)
    # stall the ENGINE (not the consumer): the tiny model would otherwise
    # retire both requests before the shed probe lands
    faults = FaultInjector({"token_stall": {"every": 1, "sleep": 0.1}})

    async def poll(server, want):
        for _ in range(400):
            _, h = await request_json("127.0.0.1", server.port, "GET",
                                      "/v1/health")
            if want(h):
                return
            await asyncio.sleep(0.005)
        raise AssertionError("server never reached the wanted state")

    async def main():
        cb, server = await serve_env(dbm, params, num_slots=1, max_queue=1,
                                     faults=faults)
        # sequence the two streams so admission is deterministic: the first
        # must be ACTIVE (not queued) before the second is submitted,
        # otherwise the second itself gets shed and the probe sees an empty
        # queue
        tasks = [asyncio.ensure_future(
            stream_generate("127.0.0.1", server.port, prompts[0], 10))]
        await poll(server, lambda h: h["active_slots"] >= 1
                   and h["queued"] == 0)
        tasks.append(asyncio.ensure_future(
            stream_generate("127.0.0.1", server.port, prompts[1], 10)))
        await poll(server, lambda h: h["queued"] >= 1)
        code, obj, hdrs = await request_json(
            "127.0.0.1", server.port, "POST", "/v1/generate",
            {"prompt": [1, 2, 3], "max_new": 2, "stream": False},
            return_headers=True)
        hi = await stream_generate("127.0.0.1", server.port, [1, 2, 3], 2,
                                   priority="interactive")
        rets = await asyncio.gather(*tasks)
        _, health = await request_json("127.0.0.1", server.port, "GET",
                                       "/v1/health")
        await server.aclose()
        return cb, code, obj, hdrs, hi, rets, health

    cb, code, obj, hdrs, hi, rets, health = asyncio.run(main())
    assert code == 429 and "error" in obj
    assert "retry-after" in hdrs and float(hdrs["retry-after"]) > 0
    assert obj["retry_after_s"] == float(hdrs["retry-after"])
    assert hi["status"] == 200 and len(hi["ids"]) == 2
    assert all(r["status"] == 200 for r in rets)
    assert health["shed"] == 1
    assert len(cb.free_pages) == cb.total_pages - 1


def test_health_reports_slo_and_supervision_fields(dbm_params):
    dbm, params = dbm_params

    async def main():
        cb, server = await serve_env(dbm, params, max_queue=8,
                                     shed_below_pages=1)
        try:
            _, h = await request_json("127.0.0.1", server.port, "GET",
                                      "/v1/health")
            return h
        finally:
            await server.aclose()

    h = asyncio.run(main())
    for key in ("preemptions", "restores", "deadline_cancels", "shed",
                "engine_crashes", "engine_restarts", "engine_alive",
                "max_queue", "free_pages", "total_pages", "draining"):
        assert key in h, key
    assert h["engine_alive"] is True and h["max_queue"] == 8
    assert h["preemptions"] == 0 and h["shed"] == 0


def test_slo_fields_validated_and_echoed(dbm_params):
    """Wire validation for the SLO fields, and the final payload echoes
    preemption/deadline state."""
    dbm, params = dbm_params

    async def main():
        cb, server = await serve_env(dbm, params)
        try:
            bad = [
                {"prompt": [1, 2], "max_new": 2, "priority": "vip"},
                {"prompt": [1, 2], "max_new": 2, "priority": 1.5},
                {"prompt": [1, 2], "max_new": 2, "priority": True},
                {"prompt": [1, 2], "max_new": 2, "ttft_slo_ms": -5},
                {"prompt": [1, 2], "max_new": 2, "tpot_slo_ms": "fast"},
            ]
            for payload in bad:
                code, obj = await request_json(
                    "127.0.0.1", server.port, "POST", "/v1/generate",
                    payload)
                assert code == 400 and "error" in obj, payload
            code, obj = await request_json(
                "127.0.0.1", server.port, "POST", "/v1/generate",
                {"prompt": [1, 2], "max_new": 2, "stream": False,
                 "priority": "interactive", "ttft_slo_ms": 60_000,
                 "tpot_slo_ms": 60_000})
            return code, obj
        finally:
            await server.aclose()

    code, obj = asyncio.run(main())
    assert code == 200 and obj["preempted"] == 0
    assert "deadline_blown" not in obj      # only present when it happened


# ---------------------------------------------------------------------------
# Zero-length prompts: the engine-level guard behind the HTTP 400
# ---------------------------------------------------------------------------

def test_zero_length_prompt_rejected_no_leak(dbm_params):
    """Direct ``submit`` of a zero-length prompt (or ``max_new < 1``) raises
    ``ValueError`` BEFORE any queue/slot/page state is touched. The HTTP
    frontend's 400 (covered in ``test_request_validation``) is backed by
    this engine-level guard, so embedders driving the batcher directly
    cannot wedge the scheduler with a request that could never retire
    (``stop_at`` would start satisfied, or at 0 for an empty prompt with
    ``max_new`` pinned, and the slot would spin forever). After the
    rejections the engine must serve a well-formed request normally."""
    dbm, params = dbm_params
    cb = ContinuousBatcher(dbm, params, num_slots=1, **CB_KW)
    free0 = len(cb.free_pages)
    with pytest.raises(ValueError, match="empty prompt"):
        cb.submit(np.zeros((0,), np.int32), 4)
    with pytest.raises(ValueError, match="max_new"):
        cb.submit(np.asarray([1, 2], np.int32), 0)
    with pytest.raises(ValueError, match="max_new"):
        cb.submit(np.asarray([1, 2], np.int32), -3)
    assert not cb.queue and not cb.active.any(), "rejected request enqueued"
    assert len(cb.free_pages) == free0 and not cb.page_refs, "pages leaked"

    rid = cb.submit(np.asarray([1, 2, 3], np.int32), 3)
    rng = jax.random.PRNGKey(5)
    fin = []
    while cb.has_work():
        rng, f = cb.step(rng, strict=False)
        fin.extend(f)
    assert [r.rid for r in fin] == [rid] and fin[0].error is None
    assert len(fin[0].out) == 3
    assert len(cb.free_pages) == free0 and not cb.page_refs
