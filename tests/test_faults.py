"""Fault-injection harness and engine-thread supervision tests.

``FaultInjector`` schedules are seeded and per-hook independent, so every
chaos scenario here is reproducible bit-for-bit. The supervision tests
drive the REAL serving stack (engine thread, SSE frontend) through the
failure modes production would meet: an engine-thread crash mid-flight
(supervisor restarts, spilled slots resume, streams complete with no token
loss or duplication), a crash storm past the restart budget (every stream
finishes with a terminal error instead of hanging, new work is refused
with 503 + Retry-After), and abrupt client disconnects during a crash
window.
"""
import asyncio

import jax
import numpy as np
import pytest

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core import DiffusionBlocksModel
from repro.launch.faults import FaultInjector, InjectedFault, make_injector
from repro.launch.serve import ContinuousBatcher, generate
from repro.launch.server import InferenceServer, request_json, stream_generate

TINY = ModelConfig(name="tiny-faults", family="dense", n_layers=4,
                   d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                   vocab_size=32)
CB_KW = dict(max_prompt=12, max_len=24, seg_len=3, page_size=4,
             chunk_size=4, precision="fp32")
GEN_KW = dict(precision="fp32", page_size=4, chunk_size=4)


@pytest.fixture(scope="module")
def dbm_params():
    dbm = DiffusionBlocksModel(TINY, DBConfig(num_blocks=2,
                                              overlap_gamma=0.1))
    return dbm, dbm.init(jax.random.PRNGKey(0))


def make_prompts(seed, n, lo=3, hi=10):
    rs = np.random.RandomState(seed)
    return [rs.randint(0, TINY.vocab_size, size=rs.randint(lo, hi))
            for _ in range(n)]


# ---------------------------------------------------------------------------
# FaultInjector unit behavior
# ---------------------------------------------------------------------------

def test_injector_spec_validation():
    with pytest.raises(ValueError):
        FaultInjector({"h": {}})                        # no trigger
    with pytest.raises(ValueError):
        FaultInjector({"h": {"p": 0.1, "every": 3}})    # two triggers
    assert make_injector(None) is None
    assert make_injector({}) is None
    assert make_injector({"h": {"p": 0.5}}) is not None


def test_injector_every_at_and_window():
    fi = FaultInjector({"a": {"every": 3}, "b": {"at": [2, 5]},
                        "c": {"p": 1.0, "start": 3, "stop": 5}})
    assert [fi.fire("a") for _ in range(7)] == \
        [False, False, True, False, False, True, False]
    assert [fi.fire("b") for _ in range(6)] == \
        [False, True, False, False, True, False]
    # window is half-open on 1-indexed call counts: fires at calls 3 and 4
    assert [fi.fire("c") for _ in range(6)] == \
        [False, False, True, True, False, False]
    assert fi.fire("unknown") is False                  # never counted
    assert fi.stats() == {"a": {"calls": 7, "fired": 2},
                          "b": {"calls": 6, "fired": 2},
                          "c": {"calls": 6, "fired": 2}}


def test_injector_probabilistic_schedules_are_seeded_and_independent():
    """Same seed -> same schedule; adding a second hook must not shift the
    first hook's stream (per-hook RandomState)."""
    a1 = FaultInjector({"x": {"p": 0.3}}, seed=9)
    a2 = FaultInjector({"x": {"p": 0.3}}, seed=9)
    both = FaultInjector({"x": {"p": 0.3}, "y": {"p": 0.3}}, seed=9)
    s1 = [a1.fire("x") for _ in range(50)]
    s2 = [a2.fire("x") for _ in range(50)]
    s3 = [both.fire("x") for _ in range(50)]
    assert s1 == s2 == s3
    assert any(s1) and not all(s1)
    other = FaultInjector({"x": {"p": 0.3}}, seed=10)
    assert [other.fire("x") for _ in range(50)] != s1


def test_injector_maybe_raise():
    fi = FaultInjector({"boom": {"at": [2]}})
    fi.maybe_raise("boom")
    with pytest.raises(InjectedFault, match="boom"):
        fi.maybe_raise("boom")


# ---------------------------------------------------------------------------
# Engine-thread supervision
# ---------------------------------------------------------------------------

def _serve(dbm, params, *, faults=None, num_slots=2, max_restarts=3,
           rng_seed=7):
    cb = ContinuousBatcher(dbm, params, num_slots=num_slots, faults=faults,
                           **CB_KW)
    server = InferenceServer(cb, rng=jax.random.PRNGKey(rng_seed),
                             max_restarts=max_restarts)
    return cb, server


def test_engine_crash_supervisor_restarts_and_completes(dbm_params):
    """One injected crash mid-flight: the supervisor restarts the loop,
    spilled slots restore and resume, every stream completes its FULL token
    budget exactly once (no loss, no duplication), and health reports the
    crash."""
    dbm, params = dbm_params
    faults = FaultInjector({"engine_crash": {"at": [3]}})
    prompts = make_prompts(0, 3)

    async def main():
        cb, server = _serve(dbm, params, faults=faults)
        await server.start()
        try:
            rets = await asyncio.gather(*[
                stream_generate("127.0.0.1", server.port, p, 6)
                for p in prompts])
            _, health = await request_json("127.0.0.1", server.port, "GET",
                                           "/v1/health")
            return cb, server, rets, health
        finally:
            await server.aclose()

    cb, server, rets, health = asyncio.run(main())
    for r in rets:
        assert r["status"] == 200 and len(r["ids"]) == 6
        assert r["final"].get("error") is None
    assert faults.fired["engine_crash"] == 1
    assert server.runner.crashes == 1 and server.runner.restarts == 1
    assert not server.runner.gave_up
    assert health["engine_crashes"] == 1 and health["engine_restarts"] == 1
    assert health["engine_alive"] is True
    assert cb.preemptions >= 1 and cb.restores == cb.preemptions
    assert len(cb.free_pages) == cb.total_pages - 1 and not cb.page_refs


def test_crash_recovery_is_bit_exact_single_slot(dbm_params):
    """A crash + restore must not change tokens: single-slot server, one
    request, crash injected mid-request — output equals the uninterrupted
    static ``generate`` for the same PRNGKey (recovery is rng-neutral)."""
    dbm, params = dbm_params
    prompt = make_prompts(1, 1)[0]
    faults = FaultInjector({"engine_crash": {"at": [3]}})

    async def main():
        cb, server = _serve(dbm, params, faults=faults, num_slots=1,
                            rng_seed=17)
        await server.start()
        try:
            return await stream_generate("127.0.0.1", server.port, prompt, 8)
        finally:
            await server.aclose()

    r = asyncio.run(main())
    assert r["status"] == 200 and faults.fired["engine_crash"] == 1
    direct = np.asarray(generate(dbm, params, np.asarray(prompt)[None], 8,
                                 rng=jax.random.PRNGKey(17),
                                 **GEN_KW))[0, len(prompt):]
    assert r["ids"] == [int(t) for t in direct]


def test_crash_storm_past_budget_fails_streams_cleanly(dbm_params):
    """Crash on EVERY step with ``max_restarts=2``: the supervisor gives up;
    every in-flight stream finishes with a terminal error event (nothing
    hangs), later submissions get 503 + Retry-After, and health reports the
    engine dead."""
    dbm, params = dbm_params
    faults = FaultInjector({"engine_crash": {"every": 1}})
    prompts = make_prompts(2, 2)

    async def main():
        cb, server = _serve(dbm, params, faults=faults, max_restarts=2)
        await server.start()
        try:
            rets = await asyncio.wait_for(asyncio.gather(*[
                stream_generate("127.0.0.1", server.port, p, 6)
                for p in prompts]), timeout=30)
            code, obj, hdrs = await request_json(
                "127.0.0.1", server.port, "POST", "/v1/generate",
                {"prompt": [1, 2], "max_new": 2}, return_headers=True)
            _, health = await request_json("127.0.0.1", server.port, "GET",
                                           "/v1/health")
            return rets, code, obj, hdrs, health
        finally:
            await server.aclose()

    rets, code, obj, hdrs, health = asyncio.run(main())
    for r in rets:                       # terminal error, not a hang
        assert r["final"] is not None and "error" in r["final"]
        assert "engine failed" in r["final"]["error"]
    assert code == 503 and "retry_after_s" in obj
    assert "retry-after" in hdrs
    assert health["engine_alive"] is False
    assert health["engine_crashes"] == 3          # budget 2 + the final one


def test_disconnect_storm_during_crash_window(dbm_params):
    """Clients that vanish mid-stream (hard disconnect, no cancel RPC)
    while the engine is also crashing: the server must keep serving the
    surviving clients to completion and end with a whole pool."""
    dbm, params = dbm_params
    faults = FaultInjector({"engine_crash": {"at": [4]}})
    prompts = make_prompts(3, 4)

    async def main():
        cb, server = _serve(dbm, params, faults=faults)
        await server.start()
        try:
            rets = await asyncio.gather(*[
                stream_generate("127.0.0.1", server.port, p, 8,
                                abort_after=2 if i % 2 else None)
                for i, p in enumerate(prompts)])
            # survivors done; wait for the engine to finish/GC the orphaned
            # aborted requests before checking the pool
            for _ in range(100):
                _, h = await request_json("127.0.0.1", server.port, "GET",
                                          "/v1/health")
                if h["active_slots"] == 0 and h["queued"] == 0:
                    break
                await asyncio.sleep(0.05)
            return cb, server, rets
        finally:
            await server.aclose()

    cb, server, rets = asyncio.run(main())
    for i, r in enumerate(rets):
        if i % 2:
            assert r["aborted"] and len(r["ids"]) >= 2
        else:
            assert r["status"] == 200 and len(r["ids"]) == 8
    assert not server.runner.gave_up
    assert len(cb.free_pages) == cb.total_pages - 1 and not cb.page_refs


def test_token_stall_hook_delays_delivery(dbm_params):
    """``token_stall`` sleeps inside token delivery on its seeded schedule —
    the request still completes, later segments arrive late."""
    dbm, params = dbm_params
    faults = FaultInjector({"token_stall": {"every": 2, "sleep": 0.05}})
    cb = ContinuousBatcher(dbm, params, num_slots=1, faults=faults, **CB_KW)
    times = []
    cb.token_cb = lambda req, toks: times.append(
        __import__("time").time())
    rid = cb.submit(np.arange(8, dtype=np.int32), 9)
    rng, fin = jax.random.PRNGKey(1), []
    while cb.has_work():
        rng, f = cb.step(rng)
        fin.extend(f)
    assert fin[0].rid == rid and len(fin[0].out) == 9
    assert faults.fired["token_stall"] >= 1
    gaps = [b - a for a, b in zip(times, times[1:])]
    assert any(g >= 0.045 for g in gaps)
