"""Attention substrate: naive vs chunked equivalence, decode-vs-full
consistency (incl. SWA ring buffer), DB concat-mask leakage properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import attention as A
from repro.nn import init as I


def setup(B=2, S=32, d=64, heads=4, kv=2, bias=True, key=0):
    dims = A.AttnDims(heads, kv, d // heads)
    spec = A.attention_spec(d, dims, qkv_bias=bias)
    p = I.init_params(jax.random.PRNGKey(key), spec)
    x = jax.random.normal(jax.random.PRNGKey(key + 1), (B, S, d))
    return dims, p, x


@pytest.mark.parametrize("mask_name", ["causal", "swa", "bidir"])
def test_naive_vs_chunked(mask_name):
    dims, p, x = setup()
    S = x.shape[1]
    mask = {"causal": A.causal_mask, "swa": A.sliding_window_mask(8),
            "bidir": A.bidirectional_mask}[mask_name]
    pos = jnp.arange(S)
    o1, _ = A.attention_fwd(p, x, dims, positions=pos, mask_mod=mask,
                            impl="naive")
    o2, _ = A.attention_fwd(p, x, dims, positions=pos, mask_mod=mask,
                            impl="chunked", q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


@pytest.mark.parametrize("window", [None, 8])
def test_decode_matches_full(window):
    dims, p, x = setup()
    B, S, _ = x.shape
    pos = jnp.arange(S)
    mask = A.sliding_window_mask(window) if window else A.causal_mask
    full, _ = A.attention_fwd(p, x, dims, positions=pos, mask_mod=mask,
                              impl="naive")
    cache = A.init_kv_cache(B, window or S, dims, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = A.decode_attention(p, x[:, t:t + 1], dims, cache, t,
                                      window=window)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), atol=2e-5)


def test_db_concat_mask_properties():
    """Leakage audit of the paper's App. E.4 mask: noisy slot i sees clean
    j < i and itself — never clean i (its own answer), never other noisy."""
    S = 16
    mask = A.db_concat_mask(S)(jnp.arange(2 * S), jnp.arange(2 * S))
    m = np.asarray(mask)
    for i in range(S):
        # clean half: plain causal
        assert m[i, :S][: i + 1].all() and not m[i, i + 1:S].any()
        assert not m[i, S:].any(), "clean must never see noisy"
        ni = S + i
        np.testing.assert_array_equal(m[ni, :S], np.arange(S) < i)
        noisy_row = m[ni, S:]
        assert noisy_row[i] and noisy_row.sum() == 1, \
            "noisy sees exactly itself in the noisy half"


def test_concat_forward_no_leak():
    """End-to-end: the noisy slot's output must be invariant to clean token i
    (the denoising target) but sensitive to the clean past."""
    dims, p, x = setup(S=16)
    S = 16
    stream = jnp.concatenate([x, x + 0.1], axis=1)
    pos = jnp.arange(2 * S)
    rope = jnp.concatenate([jnp.arange(S), jnp.arange(S)])
    out, _ = A.attention_fwd(p, stream, dims, positions=pos,
                             mask_mod=A.db_concat_mask(S),
                             rope_positions=rope, impl="naive")
    # perturb clean token at position 10
    stream2 = stream.at[:, 10].add(3.0)
    out2, _ = A.attention_fwd(p, stream2, dims, positions=pos,
                              mask_mod=A.db_concat_mask(S),
                              rope_positions=rope, impl="naive")
    # noisy slot 10 output unchanged (no self-leak of the clean answer)
    np.testing.assert_allclose(np.asarray(out[:, S + 10]),
                               np.asarray(out2[:, S + 10]), atol=1e-6)
    # noisy slot 11 sees clean 10 -> must change
    assert float(jnp.max(jnp.abs(out[:, S + 11] - out2[:, S + 11]))) > 1e-4


def test_gqa_grouping_matches_repeated_kv():
    """GQA must equal full MHA with kv heads repeated per group."""
    dims, p, x = setup(heads=4, kv=2, bias=False)
    S = x.shape[1]
    pos = jnp.arange(S)
    o_gqa, _ = A.attention_fwd(p, x, dims, positions=pos,
                               mask_mod=A.causal_mask, impl="naive")
    # expand kv projections to full heads: repeat each kv head G times
    d = x.shape[-1]
    hd = dims.head_dim
    wk = p["wk"].reshape(d, dims.n_kv_heads, hd)
    wv = p["wv"].reshape(d, dims.n_kv_heads, hd)
    G = dims.q_per_kv
    p_full = dict(p)
    p_full["wk"] = jnp.repeat(wk, G, axis=1).reshape(d, -1)
    p_full["wv"] = jnp.repeat(wv, G, axis=1).reshape(d, -1)
    dims_full = A.AttnDims(4, 4, hd)
    o_full, _ = A.attention_fwd(p_full, x, dims_full, positions=pos,
                                mask_mod=A.causal_mask, impl="naive")
    np.testing.assert_allclose(np.asarray(o_gqa), np.asarray(o_full),
                               atol=1e-5)
