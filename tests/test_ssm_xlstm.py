"""Recurrent substrates: Mamba2 chunked-SSD and xLSTM equivalences +
DB two-pass causal-consistency properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SSMConfig, XLSTMConfig
from repro.nn import init as I
from repro.nn import ssm as S
from repro.nn import xlstm as X


@pytest.fixture
def mamba():
    d = 64
    cfg = SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=16, chunk_size=8)
    params = I.init_params(jax.random.PRNGKey(0), S.mamba2_spec(d, cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 37, d))
    return d, cfg, params, x


def test_mamba_chunked_matches_stepwise(mamba):
    d, cfg, p, x = mamba
    y_full, st_f = S.mamba2_fwd(p, x, cfg, d)
    st = S.mamba2_init_state(2, cfg, d)
    ys = []
    for t in range(x.shape[1]):
        y, st = S.mamba2_decode_step(p, x[:, t:t + 1], cfg, d, st)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), atol=2e-5)
    np.testing.assert_allclose(np.asarray(st["h"]), np.asarray(st_f["h"]),
                               atol=2e-5)


@pytest.mark.parametrize("chunk", [4, 8, 16, 64])
def test_mamba_chunk_size_invariance(mamba, chunk):
    import dataclasses
    d, cfg, p, x = mamba
    y1, _ = S.mamba2_fwd(p, x, cfg, d)
    y2, _ = S.mamba2_fwd(p, x, dataclasses.replace(cfg, chunk_size=chunk), d)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)


def test_mamba_two_pass_identity_and_causality(mamba):
    d, cfg, p, x = mamba
    y_full, _ = S.mamba2_fwd(p, x, cfg, d)
    yc, yn = S.mamba2_two_pass(p, x, x, cfg, d)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(y_full), atol=2e-5)
    np.testing.assert_allclose(np.asarray(yn), np.asarray(y_full), atol=1e-4)
    # causality: noisy output at t depends only on clean tokens < t
    xn = x + 0.3
    _, yn1 = S.mamba2_two_pass(p, x, xn, cfg, d)
    x2 = x.at[:, 20:].set(0.0)
    _, yn2 = S.mamba2_two_pass(p, x2, xn, cfg, d)
    np.testing.assert_allclose(np.asarray(yn1[:, :20]),
                               np.asarray(yn2[:, :20]), atol=1e-5)
    assert float(jnp.max(jnp.abs(yn1[:, 21:] - yn2[:, 21:]))) > 1e-3


@pytest.fixture
def mlstm():
    d, H = 64, 4
    cfg = XLSTMConfig()
    params = I.init_params(jax.random.PRNGKey(0), X.mlstm_spec(d, H, cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 37, d))
    return d, H, cfg, params, x


def test_mlstm_parallel_chunked_recurrent_agree(mlstm):
    d, H, cfg, p, x = mlstm
    q, k, v, li, lf, z = X._mlstm_project(p, x, H)
    y_par = X._mlstm_parallel(q, k, v, li, lf)
    y_chk, _ = X._mlstm_chunked(q, k, v, li, lf, chunk=8)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_chk),
                               atol=5e-5)
    st = X.mlstm_init_state(2, H, q.shape[2] * q.shape[3])
    ys = []
    for t in range(x.shape[1]):
        st, y = X._mlstm_recurrent_step(st, q[:, t], k[:, t], v[:, t],
                                        li[:, t], lf[:, t])
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_par), atol=5e-5)


def test_mlstm_two_pass_identity(mlstm):
    d, H, cfg, p, x = mlstm
    y, _ = X.mlstm_fwd(p, x, H, cfg)
    oc, on = X.mlstm_two_pass(p, x, x, H, cfg)
    np.testing.assert_allclose(np.asarray(oc), np.asarray(y), atol=1e-5)
    np.testing.assert_allclose(np.asarray(on), np.asarray(y), atol=1e-4)


def test_slstm_fwd_matches_decode():
    d, H = 64, 4
    cfg = XLSTMConfig()
    p = I.init_params(jax.random.PRNGKey(0), X.slstm_spec(d, H, cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, d))
    y, _ = X.slstm_fwd(p, x, H, cfg)
    st = X.slstm_init_state(2, H, d)
    ys = []
    for t in range(24):
        yt, st = X.slstm_decode_step(p, x[:, t:t + 1], H, cfg, st)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y), atol=2e-5)


def test_slstm_two_pass_identity():
    d, H = 64, 4
    cfg = XLSTMConfig()
    p = I.init_params(jax.random.PRNGKey(0), X.slstm_spec(d, H, cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, d))
    y, _ = X.slstm_fwd(p, x, H, cfg)
    oc, on = X.slstm_two_pass(p, x, x, H, cfg)
    np.testing.assert_allclose(np.asarray(oc), np.asarray(y), atol=1e-5)
    np.testing.assert_allclose(np.asarray(on), np.asarray(y), atol=1e-5)
