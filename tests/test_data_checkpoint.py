"""Data pipeline determinism + tokenizer round-trip + checkpoint round-trip
(incl. block-wise save/assemble) + mid-epoch data-cursor resume parity.

Only the property-based tokenizer tests need ``hypothesis`` (dev extra);
everything else runs without it."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.checkpoint import (CheckpointCorrupt, load_blocks, load_pytree,
                              save_block, save_pytree)
from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core import DiffusionBlocksModel
from repro.data import (ByteTokenizer, GaussianMixtureImages, HostDataLoader,
                        MarkovLM, MarkovStream, Text8Tokenizer)


def test_markov_reproducible_and_legal():
    lm = MarkovLM(vocab_size=64, seed=3)
    x1 = lm.sample(np.random.RandomState(1), 8, 64)
    x2 = lm.sample(np.random.RandomState(1), 8, 64)
    np.testing.assert_array_equal(x1, x2)
    assert lm.transition_accuracy(x1) == 1.0
    # log-likelihood of real data beats random tokens
    rnd = np.random.RandomState(0).randint(0, 64, (8, 64))
    assert lm.log_likelihood(x1) > lm.log_likelihood(rnd)


def test_gaussian_images_separable():
    g = GaussianMixtureImages(num_classes=4, image_size=8, noise_scale=0.1)
    x, y = g.sample(np.random.RandomState(0), 32)
    # nearest-mean classification should be perfect at low noise
    d = ((x[:, None] - g.means[None]) ** 2).sum((-1, -2, -3))
    assert (d.argmin(1) == y).mean() == 1.0


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=30)
    @given(st.text(min_size=0, max_size=200))
    def test_byte_tokenizer_roundtrip(s):
        tok = ByteTokenizer()
        ids = tok.encode(s)
        assert tok.decode(ids) == s.encode("utf-8", errors="replace").decode(
            "utf-8", errors="replace")

    @settings(deadline=None, max_examples=30)
    @given(st.text(alphabet="abcdefghijklmnopqrstuvwxyz ", min_size=0,
                   max_size=100))
    def test_text8_tokenizer_roundtrip(s):
        tok = Text8Tokenizer()
        assert tok.decode(tok.encode(s)) == s
        assert (tok.encode(s) < tok.vocab_size - 1).all()  # never the mask id
else:
    @pytest.mark.skip(reason="dev extra: pip install -e .[dev] (hypothesis)")
    def test_tokenizer_roundtrip_property():
        pass


def test_host_loader_shards_batch():
    def gen():
        i = 0
        while True:
            yield np.arange(8)[:, None] + i
            i += 1
    dl = HostDataLoader(gen(), host_id=1, num_hosts=2)
    b = next(dl)
    np.testing.assert_array_equal(np.asarray(b)[:, 0], [4, 5, 6, 7])
    dl.close()


# ---------------------------------------------------------------------------
# mid-epoch data-cursor resume parity (fault-tolerant training)
# ---------------------------------------------------------------------------
def test_markov_stream_midepoch_resume_parity():
    """A stream rebuilt from a mid-epoch cursor delivers EXACTLY the batches
    the uninterrupted stream would have — the data half of the training
    resume-parity gate."""
    lm = MarkovLM(vocab_size=32, seed=5)
    ref = lm.stream(4, 16, seed=9)
    batches = [next(ref) for _ in range(10)]
    probe = lm.stream(4, 16, seed=9)
    for _ in range(4):
        next(probe)
    cur = probe.cursor()
    assert cur["batches"] == 4
    resumed = MarkovStream.from_cursor(cur)
    for i in range(4, 10):
        np.testing.assert_array_equal(next(resumed), batches[i])


def test_markov_stream_cursor_roundtrips_json():
    import json
    lm = MarkovLM(vocab_size=32, seed=5)
    s = lm.stream(2, 8, seed=1)
    next(s)
    cur = json.loads(json.dumps(s.cursor()))     # manifest round-trip
    np.testing.assert_array_equal(next(MarkovStream.from_cursor(cur)),
                                  next(s))


def test_host_loader_cursor_is_consumer_position():
    """``HostDataLoader.cursor()`` counts batches DELIVERED to the trainer,
    not batches the prefetch thread pulled ahead — resuming from the cursor
    replays exactly the unconsumed batches."""
    lm = MarkovLM(vocab_size=32, seed=5)
    ref = lm.stream(4, 16, seed=3)
    batches = [next(ref) for _ in range(8)]
    dl = HostDataLoader(lm.stream(4, 16, seed=3), prefetch=4)
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(next(dl)), batches[i])
    cur = dl.cursor()
    dl.close()
    assert cur["batches"] == 3                   # not 3 + prefetch depth
    resumed = HostDataLoader(MarkovStream.from_cursor(cur))
    for i in range(3, 8):
        np.testing.assert_array_equal(np.asarray(next(resumed)), batches[i])
    resumed.close()


def test_host_loader_cursor_none_without_source_cursor():
    def gen():
        while True:
            yield np.zeros((2, 2))
    dl = HostDataLoader(gen())
    next(dl)
    assert dl.cursor() is None
    dl.close()


# ---------------------------------------------------------------------------
# checkpoint round-trips + torn-write detection
# ---------------------------------------------------------------------------
def test_pytree_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "c": jnp.ones(4, jnp.bfloat16)}
    p = str(tmp_path / "ck.npz")
    save_pytree(p, tree, {"step": 7})
    out = load_pytree(p, jax.tree_util.tree_map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_atomic_write_leaves_no_temp_files(tmp_path):
    tree = {"w": jnp.ones((4, 4))}
    save_pytree(str(tmp_path / "ck.npz"), tree)
    save_pytree(str(tmp_path / "ck.npz"), tree)   # overwrite is atomic too
    assert sorted(os.listdir(tmp_path)) == ["ck.npz"]


def test_blockwise_checkpoint_assemble(tmp_path):
    cfg = ModelConfig(name="tiny", family="dense", n_layers=4, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=32)
    dbm = DiffusionBlocksModel(cfg, DBConfig(num_blocks=2))
    params = dbm.init(jax.random.PRNGKey(0))
    for b, (s, z) in enumerate(dbm.ranges):
        save_block(str(tmp_path), params, b, s, z)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    out = load_blocks(str(tmp_path), zeros, dbm.ranges)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_truncated_block_checkpoint_raises_actionable_error(tmp_path):
    """Regression: a torn/truncated block npz must raise CheckpointCorrupt
    naming the file and the remedy — never a raw zipfile traceback."""
    cfg = ModelConfig(name="tiny", family="dense", n_layers=4, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=32)
    dbm = DiffusionBlocksModel(cfg, DBConfig(num_blocks=2))
    params = dbm.init(jax.random.PRNGKey(0))
    for b, (s, z) in enumerate(dbm.ranges):
        save_block(str(tmp_path), params, b, s, z)
    victim = tmp_path / "block_01.npz"
    victim.write_bytes(victim.read_bytes()[:victim.stat().st_size // 2])
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    with pytest.raises(CheckpointCorrupt) as ei:
        load_blocks(str(tmp_path), zeros, dbm.ranges)
    msg = str(ei.value)
    assert "block_01.npz" in msg
    assert "delete the file" in msg or "earlier manifest" in msg
