"""Data pipeline determinism + tokenizer round-trip + checkpoint round-trip
(incl. block-wise save/assemble)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev extra: pip install -e .[dev]")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.checkpoint import load_blocks, load_pytree, save_block, save_pytree  # noqa: E402
from repro.configs import DBConfig  # noqa: E402
from repro.configs.base import ModelConfig  # noqa: E402
from repro.core import DiffusionBlocksModel  # noqa: E402
from repro.data import (ByteTokenizer, GaussianMixtureImages, HostDataLoader,  # noqa: E402
                        MarkovLM, Text8Tokenizer)


def test_markov_reproducible_and_legal():
    lm = MarkovLM(vocab_size=64, seed=3)
    x1 = lm.sample(np.random.RandomState(1), 8, 64)
    x2 = lm.sample(np.random.RandomState(1), 8, 64)
    np.testing.assert_array_equal(x1, x2)
    assert lm.transition_accuracy(x1) == 1.0
    # log-likelihood of real data beats random tokens
    rnd = np.random.RandomState(0).randint(0, 64, (8, 64))
    assert lm.log_likelihood(x1) > lm.log_likelihood(rnd)


def test_gaussian_images_separable():
    g = GaussianMixtureImages(num_classes=4, image_size=8, noise_scale=0.1)
    x, y = g.sample(np.random.RandomState(0), 32)
    # nearest-mean classification should be perfect at low noise
    d = ((x[:, None] - g.means[None]) ** 2).sum((-1, -2, -3))
    assert (d.argmin(1) == y).mean() == 1.0


@settings(deadline=None, max_examples=30)
@given(st.text(min_size=0, max_size=200))
def test_byte_tokenizer_roundtrip(s):
    tok = ByteTokenizer()
    ids = tok.encode(s)
    assert tok.decode(ids) == s.encode("utf-8", errors="replace").decode(
        "utf-8", errors="replace")


@settings(deadline=None, max_examples=30)
@given(st.text(alphabet="abcdefghijklmnopqrstuvwxyz ", min_size=0,
               max_size=100))
def test_text8_tokenizer_roundtrip(s):
    tok = Text8Tokenizer()
    assert tok.decode(tok.encode(s)) == s
    assert (tok.encode(s) < tok.vocab_size - 1).all()  # never the mask id


def test_host_loader_shards_batch():
    def gen():
        i = 0
        while True:
            yield np.arange(8)[:, None] + i
            i += 1
    dl = HostDataLoader(gen(), host_id=1, num_hosts=2)
    b = next(dl)
    np.testing.assert_array_equal(np.asarray(b)[:, 0], [4, 5, 6, 7])
    dl.close()


def test_pytree_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "c": jnp.ones(4, jnp.bfloat16)}
    p = str(tmp_path / "ck.npz")
    save_pytree(p, tree, {"step": 7})
    out = load_pytree(p, jax.tree_util.tree_map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_blockwise_checkpoint_assemble(tmp_path):
    cfg = ModelConfig(name="tiny", family="dense", n_layers=4, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=32)
    dbm = DiffusionBlocksModel(cfg, DBConfig(num_blocks=2))
    params = dbm.init(jax.random.PRNGKey(0))
    for b, (s, z) in enumerate(dbm.ranges):
        save_block(str(tmp_path), params, b, s, z)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    out = load_blocks(str(tmp_path), zeros, dbm.ranges)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
