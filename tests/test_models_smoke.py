"""Per-assigned-architecture smoke tests: reduced config (≤2 layers,
d_model≤512, ≤4 experts) — one forward, one DB train step, one decode step.
Asserts output shapes and finiteness (no NaNs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import DBConfig
from repro.configs.base import TrainConfig
from repro.core import DiffusionBlocksModel
from repro.core.training import make_db_train_step

ARCHS = configs.list_archs()


def make_aux(cfg, model, params, B, ctx):
    if cfg.family == "vlm":
        return {"image_embs": 0.1 * jax.random.normal(
            jax.random.PRNGKey(9), (B, cfg.n_image_tokens, cfg.d_model))}
    if cfg.family == "audio":
        return {"audio_embs": 0.1 * jax.random.normal(
            jax.random.PRNGKey(9), (B, cfg.n_audio_frames, cfg.d_model))}
    return None


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_constraints(arch):
    cfg = configs.reduced(configs.get_config(arch))
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4


def make_dbm(cfg, blocks=2):
    n_units = DiffusionBlocksModel(cfg, DBConfig(num_blocks=1)).model.n_units
    return DiffusionBlocksModel(
        cfg, DBConfig(num_blocks=min(blocks, n_units), overlap_gamma=0.1))


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_db_train_step(arch):
    cfg = configs.reduced(configs.get_config(arch))
    dbm = make_dbm(cfg)
    params = dbm.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    aux = make_aux(cfg, dbm.model, params, B, None)

    # e2e forward (vanilla network with inert conditioning)
    loss_e2e, _ = dbm.e2e_loss(params, tokens, aux_inputs=aux)
    assert np.isfinite(float(loss_e2e))

    # one DB train step per block: loss finite, shapes preserved
    tcfg = TrainConfig(steps=4, lr=1e-3, warmup_steps=1)
    for b in range(dbm.num_blocks):
        init_opt, step = make_db_train_step(dbm, b, tcfg)
        opt = init_opt(params)
        p2, opt, loss, m = step(params, opt, tokens, jax.random.PRNGKey(2),
                                aux)
        assert np.isfinite(float(loss)), (arch, b)
        for (path, a), (_, c) in zip(
                jax.tree_util.tree_flatten_with_path(p2)[0],
                jax.tree_util.tree_flatten_with_path(params)[0]):
            assert a.shape == c.shape, (arch, path)
            assert bool(jnp.all(jnp.isfinite(a.astype(jnp.float32)))), \
                (arch, b, path)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = configs.reduced(configs.get_config(arch))
    dbm = make_dbm(cfg)
    params = dbm.init(jax.random.PRNGKey(0))
    B = 2
    aux = make_aux(cfg, dbm.model, params, B, None)
    cache = dbm.model.init_cache(B, 32, jnp.float32)
    tok, new_cache = dbm.serve_step(params, cache, 0, jax.random.PRNGKey(3),
                                    aux_inputs=aux)
    assert tok.shape == (B,)
    assert tok.dtype in (jnp.int32, jnp.int64)
    assert bool(jnp.all((tok >= 0) & (tok < cfg.vocab_size)))
    for leaf in jax.tree_util.tree_leaves(new_cache):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "zamba2-7b", "xlstm-125m",
                                  "h2o-danube-3-4b"])
def test_prefill_then_decode_consistency(arch):
    """Prefill-produced caches and commit-produced caches must agree for the
    attention entries (same clean stream)."""
    cfg = configs.reduced(configs.get_config(arch))
    dbm = make_dbm(cfg)
    params = dbm.init(jax.random.PRNGKey(0))
    B, S = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    cache = dbm.model.init_cache(B, S, jnp.float32)
    ctx0 = dbm.make_ctx(params, 1, "decode")
    ctx0.positions = None
    for t in range(S):
        cache = dbm.commit_token(params, cache, t, tokens[:, t:t + 1], ctx0)
    _, pre_cache = dbm.prefill(params, tokens)
    flat_c = jax.tree_util.tree_leaves(cache)
    flat_p = jax.tree_util.tree_leaves(pre_cache)
    checked = 0
    for c, p in zip(flat_c, flat_p):
        if c.shape == p.shape and c.ndim >= 3:
            np.testing.assert_allclose(np.asarray(c, np.float32),
                                       np.asarray(p, np.float32), atol=2e-3)
            checked += 1
    assert checked > 0
