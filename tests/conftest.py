import os
import sys

# keep tests on 1 real device (the dry-run subprocess sets its own count)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)


def assert_close(a, b, atol=2e-4, rtol=2e-4, msg=""):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               atol=atol, rtol=rtol, err_msg=msg)
