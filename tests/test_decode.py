"""Decode-engine tests: scan-fused generation parity with the per-token
reference loop (greedy; uniform and ragged prompts), paged-vs-dense decode
attention, flash-decode kernel routing, paged-commit vs prefill cache
consistency, precision-policy cache dtypes, continuous batching, and jit
compile-cache behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core import DiffusionBlocksModel
from repro.launch.serve import ContinuousBatcher, generate, get_engine
from repro.nn import attention as A
from repro.nn import cache as KVC
from repro.nn import init as I

TINY = ModelConfig(name="tiny-decode", family="dense", n_layers=6, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=32)


def make_dbm(cfg=TINY, blocks=3):
    n_units = DiffusionBlocksModel(cfg, DBConfig(num_blocks=1)).model.n_units
    return DiffusionBlocksModel(
        cfg, DBConfig(num_blocks=min(blocks, n_units), overlap_gamma=0.1))


@pytest.fixture(scope="module")
def dbm_params():
    dbm = make_dbm()
    return dbm, dbm.init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Scan-fused vs per-token reference loop: greedy must be bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S0", [3, 8])
def test_scan_matches_reference_loop(dbm_params, S0):
    dbm, params = dbm_params
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, S0), 0,
                                 TINY.vocab_size)
    kw = dict(rng=jax.random.PRNGKey(7))
    out_scan = generate(dbm, params, prompts, 6, **kw)
    out_loop = generate(dbm, params, prompts, 6, reference=True, **kw)
    np.testing.assert_array_equal(np.asarray(out_scan), np.asarray(out_loop))


def test_scan_matches_reference_loop_ragged(dbm_params):
    dbm, params = dbm_params
    prompts = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0,
                                 TINY.vocab_size)
    plens = np.array([3, 8, 5, 6])
    kw = dict(rng=jax.random.PRNGKey(7), prompt_lengths=plens)
    out_scan = generate(dbm, params, prompts, 6, **kw)
    out_loop = generate(dbm, params, prompts, 6, reference=True, **kw)
    np.testing.assert_array_equal(np.asarray(out_scan), np.asarray(out_loop))
    # generated tokens sit immediately after each slot's ragged prompt
    out = np.asarray(out_scan)
    for b, pl in enumerate(plens):
        np.testing.assert_array_equal(out[b, :pl],
                                      np.asarray(prompts)[b, :pl])


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["zamba2-7b", "xlstm-125m",
                                  "h2o-danube-3-4b"])
def test_scan_matches_reference_loop_families(arch):
    """Recurrent-state masking (hybrid mamba / xlstm) and SWA window masking
    through the paged engine, ragged prompts."""
    cfg = configs.reduced(configs.get_config(arch))
    dbm = make_dbm(cfg, blocks=2)
    params = dbm.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 6), 0,
                                 cfg.vocab_size)
    plens = np.array([3, 6, 4])
    kw = dict(rng=jax.random.PRNGKey(7), prompt_lengths=plens)
    o1 = generate(dbm, params, prompts, 4, **kw)
    o2 = generate(dbm, params, prompts, 4, reference=True, **kw)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_sampling_traced_and_deterministic(dbm_params):
    dbm, params = dbm_params
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 4), 0,
                                 TINY.vocab_size)
    kw = dict(rng=jax.random.PRNGKey(9), temperature=0.8, top_k=8)
    o1 = generate(dbm, params, prompts, 5, **kw)
    o2 = generate(dbm, params, prompts, 5, **kw)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    assert np.all((np.asarray(o1) >= 0) & (np.asarray(o1) < TINY.vocab_size))


# ---------------------------------------------------------------------------
# Paged decode attention vs the dense reference path
# ---------------------------------------------------------------------------

def _attn_setup(B=2, S=12, d=64, heads=4, kv=2, key=0):
    dims = A.AttnDims(heads, kv, d // heads)
    p = I.init_params(jax.random.PRNGKey(key), A.attention_spec(d, dims))
    x = jax.random.normal(jax.random.PRNGKey(key + 1), (B, S, d))
    return dims, p, x


@pytest.mark.parametrize("impl", ["auto", "kernels"])
def test_paged_decode_matches_dense(impl):
    """Token-by-token: the paged path (uniform lengths) must reproduce the
    dense decode_attention outputs <=1e-4 fp32."""
    dims, p, x = _attn_setup()
    B, S, d = x.shape
    psz = 4
    pps = KVC.pages_for(S, psz)
    pkv = KVC.init_paged_kv(1 + B * pps, psz, dims, jnp.float32)
    table = KVC.identity_page_table(B, pps)
    dense = A.init_kv_cache(B, S, dims, jnp.float32)
    for t in range(S):
        xt = x[:, t:t + 1]
        o_dense, dense = A.decode_attention(p, xt, dims, dense, t)
        lengths = jnp.full((B,), t, jnp.int32)
        o_paged, pkv = KVC.paged_decode_attention(
            p, xt, dims, pkv, lengths=lengths, page_table=table, impl=impl)
        np.testing.assert_allclose(np.asarray(o_paged), np.asarray(o_dense),
                                   atol=1e-4, rtol=1e-4)


def test_dense_decode_attention_kernel_route():
    """decode_attention(impl='kernels') — the dense cache viewed as pages
    through the flash-decode kernel — matches the reference path <=1e-4."""
    dims, p, x = _attn_setup(key=3)
    B, S, _ = x.shape
    c_ref = A.init_kv_cache(B, S, dims, jnp.float32)
    c_ker = A.init_kv_cache(B, S, dims, jnp.float32)
    for t in range(S):
        o_ref, c_ref = A.decode_attention(p, x[:, t:t + 1], dims, c_ref, t)
        o_ker, c_ker = A.decode_attention(p, x[:, t:t + 1], dims, c_ker, t,
                                          impl="kernels")
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                                   atol=1e-4, rtol=1e-4)


def test_dense_kernel_route_swa_ring_matches_reference():
    """The SWA ring buffer is un-rotated into absolute order and served
    through the paged flash-decode kernel; token-by-token outputs must match
    the reference masked attend over the ring — including the wrap-around
    steps (pos >= window) and the not-yet-full prefix (pos < window)."""
    dims, p, x = _attn_setup(S=14, key=4)
    B, S, _ = x.shape
    window = 6
    c_ref = A.init_kv_cache(B, window, dims, jnp.float32)
    c_ker = A.init_kv_cache(B, window, dims, jnp.float32)
    for t in range(S):
        o_ref, c_ref = A.decode_attention(p, x[:, t:t + 1], dims, c_ref, t,
                                          window=window)
        o_ker, c_ker = A.decode_attention(p, x[:, t:t + 1], dims, c_ker, t,
                                          window=window, impl="kernels")
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                                   atol=1e-4, rtol=1e-4, err_msg=f"pos {t}")


def test_paged_append_trash_redirect():
    """Inactive slots must not corrupt live pages: their writes land on the
    reserved trash page."""
    dims = A.AttnDims(2, 2, 8)
    pkv = KVC.init_paged_kv(1 + 2, 4, dims, jnp.float32)
    table = KVC.identity_page_table(2, 1)
    k_new = jnp.ones((2, 2, 8))
    lengths = jnp.zeros((2,), jnp.int32)
    out = KVC.append_paged(pkv, k_new, k_new, table, lengths,
                           active=jnp.asarray([True, False]))
    assert float(jnp.sum(jnp.abs(out.k[1]))) > 0      # slot 0's page written
    assert float(jnp.sum(jnp.abs(out.k[2]))) == 0     # slot 1 redirected
    assert float(jnp.sum(jnp.abs(out.k[0, 0]))) > 0   # ... to the trash page


# ---------------------------------------------------------------------------
# Paged commit scan vs full-sequence prefill
# ---------------------------------------------------------------------------

def test_paged_commit_matches_prefill(dbm_params):
    """The engine's prefill (per-token commits into pages) must agree with
    the full-sequence prefill caches for the attention entries."""
    dbm, params = dbm_params
    B, S0, psz = 2, 8, 4
    prompts = jax.random.randint(jax.random.PRNGKey(5), (B, S0), 0,
                                 TINY.vocab_size)
    eng = get_engine(dbm, steps_per_block=1, temperature=0.0, top_k=0,
                     precision="fp32", impl="auto")
    pps = KVC.pages_for(S0, psz)
    kv = dbm.model.init_paged_cache(B, 1 + B * pps, psz, eng.pol)
    table = KVC.identity_page_table(B, pps)
    plens = jnp.full((B,), S0, jnp.int32)
    kv, lengths = eng._prefill(params, kv, table, jnp.zeros((B,), jnp.int32),
                               prompts.astype(jnp.int32), plens,
                               jnp.zeros((B,), jnp.int32))
    assert np.all(np.asarray(lengths) == S0)
    _, pre = dbm.prefill(params, prompts)
    # gather the paged pool back into logical (units, B, S, KV, hd)
    for paged, dense in ((kv, pre),):
        k_log = paged["k"] if isinstance(paged, dict) else paged.k
        k_log = k_log[:, table]                    # (units, B, pps, psz, ...)
        k_log = k_log.reshape(k_log.shape[0], B, pps * psz,
                              *k_log.shape[4:])[:, :, :S0]
        np.testing.assert_allclose(np.asarray(k_log, np.float32),
                                   np.asarray(dense["k"], np.float32),
                                   atol=2e-3)


# ---------------------------------------------------------------------------
# Precision policy: bf16 KV storage, fp32 recurrent states
# ---------------------------------------------------------------------------

def test_paged_cache_dtype_follows_policy():
    dbm = make_dbm()
    kv16 = dbm.model.init_paged_cache(2, 5, 4, "bf16")
    assert kv16.k.dtype == jnp.bfloat16
    kv32 = dbm.model.init_paged_cache(2, 5, 4, "fp32")
    assert kv32.k.dtype == jnp.float32
    # default policy (None) is fp32 — serving passes bf16 explicitly
    assert dbm.model.init_paged_cache(2, 5, 4).k.dtype == jnp.float32


@pytest.mark.slow
def test_hybrid_paged_cache_states_stay_fp32():
    cfg = configs.reduced(configs.get_config("zamba2-7b"))
    dbm = make_dbm(cfg, blocks=2)
    kv = dbm.model.init_paged_cache(2, 5, 4, "bf16")
    assert kv["shared_kv"].k.dtype == jnp.bfloat16      # attention KV paged
    for leaf in jax.tree_util.tree_leaves(kv["mamba"]):
        assert leaf.dtype == jnp.float32                # recurrence override


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

def test_continuous_batching_completes_and_reclaims_pages(dbm_params):
    dbm, params = dbm_params
    cb = ContinuousBatcher(dbm, params, num_slots=2, max_prompt=8,
                           max_len=16, seg_len=4, page_size=4)
    d0 = cb.eng.dispatches       # engine is memoized across tests
    rs = np.random.RandomState(0)
    rids = [cb.submit(rs.randint(0, TINY.vocab_size, size=rs.randint(3, 9)),
                      max_new=6) for _ in range(5)]
    done = cb.run(jax.random.PRNGKey(3))
    assert [r.rid for r in done] == rids
    assert all(len(r.out) == 6 for r in done)
    assert all(0 <= t < TINY.vocab_size for r in done for t in r.out)
    # every page returned to the pool after retirement
    assert len(cb.free_pages) == cb.total_pages - 1
    # scan fusion: far fewer dispatches than scan steps executed
    assert (cb.eng.dispatches - d0) * 2 <= cb.steps


def test_reset_paged_slots_restores_init_state():
    """Recycling a slot must restore its recurrent state to the INIT values
    (xlstm max-stabilizers init to -1e30, not 0) without touching the other
    slots. Leaves are (units, B, ...)."""
    cfg = configs.reduced(configs.get_config("xlstm-125m"))
    dbm = make_dbm(cfg, blocks=2)
    kv = dbm.model.init_paged_cache(3, 4, 4, "bf16")
    dirty = jax.tree_util.tree_map(lambda x: x + 1.0, kv)
    out = dbm.model.reset_paged_slots(dirty,
                                      jnp.asarray([True, False, True]))
    for fresh, got, was in zip(jax.tree_util.tree_leaves(kv),
                               jax.tree_util.tree_leaves(out),
                               jax.tree_util.tree_leaves(dirty)):
        fresh, got, was = (np.asarray(x, np.float32)
                           for x in (fresh, got, was))
        np.testing.assert_array_equal(got[:, 0], fresh[:, 0])   # reset
        np.testing.assert_array_equal(got[:, 2], fresh[:, 2])
        np.testing.assert_array_equal(got[:, 1], was[:, 1])     # held


def test_reset_paged_slots_dense_noop_and_hybrid_axis():
    dbm = make_dbm()
    kv = dbm.model.init_paged_cache(2, 4, 4, "bf16")
    assert dbm.model.reset_paged_slots(kv, jnp.asarray([True, True])) is kv
    cfg = configs.reduced(configs.get_config("zamba2-7b"))
    hyb = make_dbm(cfg, blocks=2)
    kvh = hyb.model.init_paged_cache(2, 4, 4, "bf16")
    dirty = dict(kvh, mamba=jax.tree_util.tree_map(lambda x: x + 1.0,
                                                   kvh["mamba"]))
    out = hyb.model.reset_paged_slots(dirty, jnp.asarray([False, True]))
    for leaf in jax.tree_util.tree_leaves(out["mamba"]):
        arr = np.asarray(leaf, np.float32)      # (units, inner, B, ...)
        assert np.all(arr[:, :, 1] == 0) and np.all(arr[:, :, 0] == 1)
    assert out["shared_kv"] is dirty["shared_kv"]   # paged KV untouched


@pytest.mark.slow
def test_continuous_slot_reuse_does_not_leak_state():
    """A recycled slot's SECOND request must be independent of its first
    occupant: serve [p1, p2] and [p1', p2] (same lengths, different tokens)
    through ONE slot — p2's greedy output must be identical. Catches both
    stale recurrent state and stale KV pages leaking across requests."""
    cfg = configs.reduced(configs.get_config("xlstm-125m"))
    dbm = make_dbm(cfg, blocks=2)
    params = dbm.init(jax.random.PRNGKey(0))
    rs = np.random.RandomState(4)
    p1 = rs.randint(0, cfg.vocab_size, size=5)
    p1_alt = (p1 + 7) % cfg.vocab_size
    p2 = rs.randint(0, cfg.vocab_size, size=5)

    def serve(first):
        cb = ContinuousBatcher(dbm, params, num_slots=1, max_prompt=6,
                               max_len=12, seg_len=4, page_size=4)
        cb.submit(first, max_new=5)
        cb.submit(p2, max_new=5)
        done = cb.run(jax.random.PRNGKey(9))
        return done[1].out

    assert serve(p1) == serve(p1_alt)


def test_continuous_batching_rejects_oversized_request(dbm_params):
    dbm, params = dbm_params
    cb = ContinuousBatcher(dbm, params, num_slots=1, max_prompt=8,
                           max_len=16, seg_len=4, page_size=4, total_pages=2)
    cb.submit(np.arange(8) % TINY.vocab_size, max_new=8)   # needs 4 pages
    with pytest.raises(RuntimeError):
        cb.run(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Cancellation (PR 6): queued and admitted aborts must free pages exactly —
# these extend the leak tests above to the ``cancel(rid)`` path.
# ---------------------------------------------------------------------------

def test_cancel_queued_request_dropped_before_admission(dbm_params):
    dbm, params = dbm_params
    cb = ContinuousBatcher(dbm, params, num_slots=1, max_prompt=8,
                           max_len=16, seg_len=4, page_size=4)
    rs = np.random.RandomState(21)
    rids = [cb.submit(rs.randint(0, TINY.vocab_size, size=5), max_new=4)
            for _ in range(3)]
    assert cb.cancel(rids[1])
    done = cb.run(jax.random.PRNGKey(0))
    by_rid = {r.rid: r for r in done}
    assert set(by_rid) == set(rids)       # cancelled requests are reported
    assert by_rid[rids[1]].cancelled and by_rid[rids[1]].out == []
    assert len(by_rid[rids[0]].out) == 4 and len(by_rid[rids[2]].out) == 4
    assert cb.cancelled_count == 1
    assert len(cb.free_pages) == cb.total_pages - 1
    assert not cb.cancel(rids[1])         # unknown/finished rid -> False


def test_cancel_active_request_frees_pages_mid_flight(dbm_params):
    dbm, params = dbm_params
    cb = ContinuousBatcher(dbm, params, num_slots=2, max_prompt=8,
                           max_len=16, seg_len=4, page_size=4)
    rs = np.random.RandomState(22)
    rid_a = cb.submit(rs.randint(0, TINY.vocab_size, size=6), max_new=8)
    rid_b = cb.submit(rs.randint(0, TINY.vocab_size, size=6), max_new=8)
    rng = jax.random.PRNGKey(1)
    rng, fin = cb.step(rng)               # admit both + first decode segment
    assert not fin and int(cb.active.sum()) == 2
    assert cb.cancel(rid_a)
    rng, fin = cb.step(rng)               # cancel applies BEFORE the segment
    cancelled = [r for r in fin if r.rid == rid_a]
    assert cancelled and cancelled[0].cancelled
    assert 0 < len(cancelled[0].out) < 8  # aborted mid-generation
    assert not cancelled[0].pages         # its pages went back to the pool
    finished = list(fin)
    while cb.has_work():
        rng, fin = cb.step(rng)
        finished.extend(fin)
    b = [r for r in finished if r.rid == rid_b][0]
    assert not b.cancelled and len(b.out) == 8   # neighbor unaffected
    assert len(cb.free_pages) == cb.total_pages - 1
    assert not cb.active.any() and not cb.page_refs


def test_cancel_respects_prefix_cache_refcounts(dbm_params):
    """Cancelling a request that maps shared prefix pages must only drop the
    SLOT's refs: the cache-retained chain survives and still serves later
    requests."""
    dbm, params = dbm_params
    rs = np.random.RandomState(23)
    sys_p = rs.randint(0, TINY.vocab_size, size=16)    # 4 full pages of 4
    u1 = rs.randint(0, TINY.vocab_size, size=4)
    u2 = rs.randint(0, TINY.vocab_size, size=4)
    cb = ContinuousBatcher(dbm, params, num_slots=1, max_prompt=24,
                           max_len=32, seg_len=4, page_size=4,
                           chunk_size=8, prefix_cache=True,
                           precision="fp32")
    cb.submit(np.concatenate([sys_p, u1]), max_new=4)
    cb.run(jax.random.PRNGKey(0))
    retained = set(cb.page_refs)          # prefix pages held by the cache
    rid = cb.submit(np.concatenate([sys_p, u2]), max_new=8)
    rng = jax.random.PRNGKey(1)
    rng, fin = cb.step(rng)
    req = cb.slot_req[0]
    assert req is not None and req.shared_tokens == 16
    assert any(cb.page_refs.get(p, 0) > 1 for p in req.pages)  # truly shared
    assert cb.cancel(rid)
    rng, fin = cb.step(rng)
    assert fin and fin[0].cancelled
    # slot refs dropped, cache refs intact, nothing double-freed
    assert all(v == 1 for v in cb.page_refs.values())
    assert retained <= set(cb.page_refs)
    assert len(cb.free_pages) + len(cb.page_refs) == cb.total_pages - 1
    # the surviving chain still serves a later request end to end
    cb.submit(np.concatenate([sys_p, u2]), max_new=4)
    done = cb.run(jax.random.PRNGKey(2))
    assert done[0].shared_tokens >= 16 and len(done[0].out) == 4


def test_recycled_slot_after_cancel_no_leak(dbm_params):
    """The PR 3/4 leak property under cancellation: a slot recycled from a
    CANCELLED occupant must serve its next request identically regardless of
    what the cancelled request was."""
    dbm, params = dbm_params
    rs = np.random.RandomState(24)
    p1 = rs.randint(0, TINY.vocab_size, size=8)
    p1_alt = (p1 + 7) % TINY.vocab_size
    p2 = rs.randint(0, TINY.vocab_size, size=8)

    def serve(first):
        cb = ContinuousBatcher(dbm, params, num_slots=1, max_prompt=12,
                               max_len=20, seg_len=4, page_size=4,
                               chunk_size=4, precision="fp32")
        rid1 = cb.submit(first, max_new=8)
        rng = jax.random.PRNGKey(9)
        rng, _ = cb.step(rng)             # chunk 1 of the prompt
        rng, _ = cb.step(rng)             # chunk 2 + first decode segment
        assert len(cb.slot_req[0].out) == 4   # mid-generation
        cb.cancel(rid1)
        rng, fin = cb.step(rng)
        assert fin[0].cancelled
        cb.submit(p2, max_new=5)
        out = []
        while cb.has_work():
            rng, fin = cb.step(rng)
            out.extend(fin)
        assert len(cb.free_pages) == cb.total_pages - 1
        return out[0].out

    assert serve(p1) == serve(p1_alt)


# ---------------------------------------------------------------------------
# Compile-cache behavior (static steps_per_block / sampler config)
# ---------------------------------------------------------------------------

def test_engine_memoized_and_jit_cache_stable(dbm_params):
    dbm, params = dbm_params
    kw = dict(steps_per_block=1, temperature=0.0, top_k=0,
              precision="bf16", impl="auto")
    assert get_engine(dbm, **kw) is get_engine(dbm, **kw)
    assert get_engine(dbm, **dict(kw, steps_per_block=2)) is not \
        get_engine(dbm, **kw)
    eng = get_engine(dbm, **kw)
    prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 4), 0,
                                 TINY.vocab_size)
    eng.generate(params, prompts, 3, jax.random.PRNGKey(0))
    if hasattr(eng._decode, "_cache_size"):
        n = eng._decode._cache_size()
        eng.generate(params, prompts, 3, jax.random.PRNGKey(1))
        assert eng._decode._cache_size() == n      # same shapes: no retrace


# ---------------------------------------------------------------------------
# Slot recycling under prefix sharing (PR 4): retiring a slot must only free
# pages whose refcount drops to zero, and a recycled slot must not observe a
# prior tenant's pages.
# ---------------------------------------------------------------------------

def test_retire_under_sharing_frees_only_zero_ref_pages(dbm_params):
    """Serve two prefix-sharing requests through ONE slot. Retiring the
    first must NOT free the shared prefix pages (the cache and later the
    second slot still hold refs); after both retire, exactly the
    cache-retained pages stay out of the free list."""
    dbm, params = dbm_params
    rs = np.random.RandomState(11)
    sys_p = rs.randint(0, TINY.vocab_size, size=16)    # 4 full pages of 4
    u1 = rs.randint(0, TINY.vocab_size, size=4)
    u2 = rs.randint(0, TINY.vocab_size, size=4)
    cb = ContinuousBatcher(dbm, params, num_slots=1, max_prompt=24,
                           max_len=32, seg_len=4, page_size=4,
                           chunk_size=8, prefix_cache=True,
                           precision="fp32")
    cb.submit(np.concatenate([sys_p, u1]), max_new=4)
    cb.run(jax.random.PRNGKey(0))
    # first request retired: its prefix pages survive as cache-held refs
    retained_after_1 = set(cb.page_refs)
    assert retained_after_1, "prefix pages should stay cache-retained"
    assert all(r == 1 for r in cb.page_refs.values())
    assert len(cb.free_pages) + len(cb.page_refs) == cb.total_pages - 1
    cb.submit(np.concatenate([sys_p, u2]), max_new=4)
    done = cb.run(jax.random.PRNGKey(1))
    assert done[0].shared_tokens == 16
    # second request retired too: shared pages still retained exactly once
    assert set(cb.page_refs) >= retained_after_1
    assert all(r == 1 for r in cb.page_refs.values())
    assert len(cb.free_pages) + len(cb.page_refs) == cb.total_pages - 1


def test_recycled_slot_no_leak_under_prefix_sharing(dbm_params):
    """PR 3's leak test, under prefix sharing: a recycled slot's SECOND
    request must be independent of its first occupant — serve [p1, p2] and
    [p1', p2] (same lengths, different tokens) through ONE slot with the
    prefix cache ON; p2's greedy output must be identical. Catches stale
    pages leaking through the recycled slot AND through the prefix trie."""
    dbm, params = dbm_params
    rs = np.random.RandomState(12)
    p1 = rs.randint(0, TINY.vocab_size, size=8)
    p1_alt = (p1 + 7) % TINY.vocab_size
    p2 = rs.randint(0, TINY.vocab_size, size=8)

    def serve(first):
        cb = ContinuousBatcher(dbm, params, num_slots=1, max_prompt=12,
                               max_len=20, seg_len=4, page_size=4,
                               chunk_size=4, prefix_cache=True,
                               precision="fp32")
        cb.submit(first, max_new=5)
        cb.submit(p2, max_new=5)
        done = cb.run(jax.random.PRNGKey(9))
        assert done[1].shared_tokens == 0     # p2 shares nothing with p1
        return done[1].out

    assert serve(p1) == serve(p1_alt)
