"""Preemption, page spill/restore, SLO deadlines, and admission control —
engine-level tests against the REAL jitted dispatch programs.

The core acceptance gate is DIFFERENTIAL BIT-PARITY: a request force-
preempted mid-prefill or mid-decode (its KV pages and per-slot cross state
spilled to host numpy, its slot and pages returned to the pool, then
restored into different physical pages at re-admission) must produce
exactly the token sequence of an uninterrupted run with the same PRNGKey.
The spill round trip is rng-neutral — no dispatch runs for a spilled slot —
so greedy outputs must match token for token, for an unconditioned (dense)
AND a conditioned (VLM cross-attention) request.

Also covered here: the spill/restore primitives round-tripping exactly
through DIFFERENT physical pages, priority preemption under genuine pool
pressure (an interactive arrival spills a batch slot and both still
complete), TTFT/TPOT deadline enforcement retiring requests with partial
output, queue-depth and pool-pressure admission control (429 semantics at
the engine layer), and allocator-exhaustion fault injection never
deadlocking or leaking pages.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import DBConfig
from repro.configs.base import ModelConfig
from repro.core import DiffusionBlocksModel
from repro.launch.faults import FaultInjector
from repro.launch.serve import AdmissionError, ContinuousBatcher
from repro.nn import cache as KVC

TINY = ModelConfig(name="tiny-preempt", family="dense", n_layers=4,
                   d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                   vocab_size=32)
TINY_VLM = ModelConfig(name="tiny-preempt-vlm", family="vlm", n_layers=4,
                       d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                       vocab_size=32, cross_attn_every=2, n_image_tokens=4)

CB_KW = dict(max_prompt=12, max_len=24, seg_len=3, page_size=4,
             chunk_size=4, precision="fp32")


@pytest.fixture(scope="module")
def dense_env():
    dbm = DiffusionBlocksModel(TINY, DBConfig(num_blocks=2,
                                              overlap_gamma=0.1))
    return dbm, dbm.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def vlm_env():
    import jax.numpy as jnp
    dbm = DiffusionBlocksModel(TINY_VLM, DBConfig(num_blocks=2,
                                                  overlap_gamma=0.1))
    params = dbm.init(jax.random.PRNGKey(0))
    # open the zero-init cross-attention gate so conditioning measurably
    # changes the greedy output (same trick as tests/test_prefill.py)
    params["units"]["cross"]["xgate"] = 2.0 * jnp.ones_like(
        params["units"]["cross"]["xgate"])
    return dbm, params


def pool_whole(cb):
    return (len(cb.free_pages) == cb.total_pages - 1
            and not cb.page_refs and not cb.active.any())


def run_with_preempt(dbm, params, prompt, max_new, *, aux=None,
                     preempt_at=None, seed=11, **kw):
    """One request through a single-slot batcher, optionally force-preempted
    before step ``preempt_at``; returns (tokens, batcher)."""
    cb = ContinuousBatcher(dbm, params, num_slots=1, **{**CB_KW, **kw})
    rid = cb.submit(np.asarray(prompt, np.int32), max_new, aux_inputs=aux)
    rng, fin, step = jax.random.PRNGKey(seed), [], 0
    while cb.has_work():
        if step == preempt_at:
            cb.preempt(rid)
        rng, f = cb.step(rng, strict=False)
        fin.extend(f)
        step += 1
        assert step < 500, "engine failed to converge"
    assert len(fin) == 1 and fin[0].rid == rid and fin[0].error is None
    return fin[0].out, cb


# ---------------------------------------------------------------------------
# Differential bit-parity: preempted == uninterrupted
# ---------------------------------------------------------------------------

def test_preempt_bit_parity_unconditioned(dense_env):
    """Force-preempting mid-prefill (step 1) and mid-decode (step 3) changes
    nothing: the spill/restore round trip consumes no rng and restores the
    exact KV content, so greedy output is bit-identical."""
    dbm, params = dense_env
    prompt = (np.arange(1, 9) * 3) % TINY.vocab_size
    base, _ = run_with_preempt(dbm, params, prompt, 8)
    for at in (1, 3):
        got, cb = run_with_preempt(dbm, params, prompt, 8, preempt_at=at)
        assert cb.preemptions >= 1 and cb.restores == cb.preemptions
        assert got == base, (at, got, base)
        assert pool_whole(cb)


def test_preempt_bit_parity_conditioned(vlm_env):
    """Same differential for a CONDITIONED request: the spill must carry the
    per-slot cross-attention block (``paged_state_axes``) alongside the KV
    pages, or the restored request silently decodes unconditioned."""
    dbm, params = vlm_env
    prompt = (np.arange(1, 9) * 5) % TINY_VLM.vocab_size
    aux = {"image_embs": 4.0 * np.random.RandomState(3)
           .randn(TINY_VLM.n_image_tokens, TINY_VLM.d_model)
           .astype(np.float32)}
    base, _ = run_with_preempt(dbm, params, prompt, 8, aux=aux)
    uncond, _ = run_with_preempt(dbm, params, prompt, 8)
    assert base != uncond, "conditioning must change the output"
    for at in (1, 3):
        got, cb = run_with_preempt(dbm, params, prompt, 8, aux=aux,
                                   preempt_at=at)
        assert cb.preemptions >= 1 and cb.restores == cb.preemptions
        assert got == base, (at, got, base)
        assert pool_whole(cb)


def test_preempt_bit_parity_int8_pool(dense_env):
    """The spill/restore round trip stays exact on a QUANTIZED pool: the
    snapshot carries the int8 page bytes plus their fp32 per-page scales,
    so a preempted request's greedy continuation is bit-identical to an
    uninterrupted int8 run — quantization is lossy, migrating the quantized
    state is not."""
    dbm, params = dense_env
    prompt = (np.arange(1, 9) * 3) % TINY.vocab_size
    base, _ = run_with_preempt(dbm, params, prompt, 8, kv_dtype="int8")
    for at in (1, 3):
        got, cb = run_with_preempt(dbm, params, prompt, 8, preempt_at=at,
                                   kv_dtype="int8")
        assert cb.preemptions >= 1 and cb.restores == cb.preemptions
        assert got == base, (at, got, base)
        assert pool_whole(cb)


def test_spill_restore_primitives_roundtrip_different_pages(vlm_env):
    """``spill_slot``/``restore_slot`` round-trip EXACTLY through different
    physical pages: page content lands at the new ids, dense per-slot rows
    (cross state) are restored bit-for-bit, and untouched slots/pages are
    unchanged."""
    import jax.numpy as jnp

    dbm, params = vlm_env
    cb = ContinuousBatcher(dbm, params, num_slots=2, **CB_KW)
    axes = dbm.model.paged_state_axes
    assert axes == {"cross": 1}
    rs = np.random.RandomState(7)
    src, dst, slot = [1, 2, 3], [5, 7, 4], 1

    flat, treedef = jax.tree_util.tree_flatten_with_path(
        cb.kv, is_leaf=lambda x: isinstance(x, KVC.PagedKV))
    filled, want = [], []
    for path, leaf in flat:
        if isinstance(leaf, KVC.PagedKV):
            shp = list(np.asarray(leaf.k.shape))
            shp[KVC.PAGE_AXIS] = len(src)
            k = rs.randn(*shp).astype(np.float32)
            v = rs.randn(*shp).astype(np.float32)
            idx = KVC._page_index(jnp.asarray(src))
            filled.append(KVC.PagedKV(k=leaf.k.at[idx].set(k),
                                      v=leaf.v.at[idx].set(v)))
            want.append((k, v))
        else:
            ax = 1                       # cross k/v: slot axis 1
            row_shp = list(leaf.shape)
            del row_shp[ax]
            row = rs.randn(*row_shp).astype(np.float32)
            sel = (slice(None),) * ax + (slot,)
            filled.append(leaf.at[sel].set(row))
            want.append(row)
    kv = jax.tree_util.tree_unflatten(treedef, filled)

    spilled = KVC.spill_slot(kv, slot, src, axes)
    assert spilled.n_pages == len(src)
    # wipe the source pages and the slot row so a lazy restore can't pass
    wiped = []
    for (path, _), leaf in zip(flat, jax.tree_util.tree_flatten(
            kv, is_leaf=lambda x: isinstance(x, KVC.PagedKV))[0]):
        if isinstance(leaf, KVC.PagedKV):
            idx = KVC._page_index(jnp.asarray(src))
            wiped.append(KVC.PagedKV(k=leaf.k.at[idx].set(0),
                                     v=leaf.v.at[idx].set(0)))
        else:
            sel = (slice(None),) + (slot,)
            wiped.append(leaf.at[sel].set(0))
    kv = jax.tree_util.tree_unflatten(treedef, wiped)

    kv = KVC.restore_slot(kv, slot, dst, spilled, axes)
    leaves = jax.tree_util.tree_flatten(
        kv, is_leaf=lambda x: isinstance(x, KVC.PagedKV))[0]
    for leaf, w in zip(leaves, want):
        if isinstance(leaf, KVC.PagedKV):
            k, v = w
            got_k = np.asarray(jnp.take(leaf.k, jnp.asarray(dst),
                                        axis=KVC.PAGE_AXIS))
            got_v = np.asarray(jnp.take(leaf.v, jnp.asarray(dst),
                                        axis=KVC.PAGE_AXIS))
            np.testing.assert_array_equal(got_k, k)
            np.testing.assert_array_equal(got_v, v)
        else:
            np.testing.assert_array_equal(np.asarray(leaf)[:, slot], w)


# ---------------------------------------------------------------------------
# Priority preemption under pool pressure
# ---------------------------------------------------------------------------

def test_interactive_preempts_batch_for_pages(dense_env):
    """A pool too small for both requests: the interactive arrival spills
    the running batch slot (strictly lower priority), completes first, and
    the batch request restores and still finishes — nobody starves, the
    pool ends whole."""
    dbm, params = dense_env
    cb = ContinuousBatcher(dbm, params, num_slots=2, total_pages=8,
                           **CB_KW)   # 7 usable pages: 5 + 3 don't fit
    rs = np.random.RandomState(0)
    lo = cb.submit(rs.randint(0, 32, size=8), 12, priority="batch")   # 5 pg
    rng = jax.random.PRNGKey(5)
    for _ in range(2):                     # admit + start prefilling batch
        rng, _ = cb.step(rng, strict=False)
    hi = cb.submit(rs.randint(0, 32, size=8), 4, priority="interactive")
    fin, order, steps = {}, [], 0
    while cb.has_work():
        rng, f = cb.step(rng, strict=False)
        for r in f:
            fin[r.rid] = r
            order.append(r.rid)
        steps += 1
        assert steps < 500, "scheduler failed to converge"
    assert order == [hi, lo], order
    assert fin[hi].error is None and len(fin[hi].out) == 4
    assert fin[lo].error is None and len(fin[lo].out) == 12
    assert fin[lo].preempt_count >= 1 and fin[hi].preempt_count == 0
    assert cb.preemptions >= 1 and cb.restores == cb.preemptions
    assert pool_whole(cb)


def test_alloc_exhaustion_fault_no_deadlock_no_leak(dense_env):
    """A flaky allocator (fault-injected ``_alloc_page`` refusals at p=0.3)
    forces the admission-unwind, CoW-relief, and self-preemption paths over
    and over; every request must still complete and the pool partition
    exactly."""
    dbm, params = dense_env
    faults = FaultInjector({"alloc_exhaust": {"p": 0.3}}, seed=1)
    cb = ContinuousBatcher(dbm, params, num_slots=2, prefix_cache=True,
                           faults=faults, **CB_KW)
    rs = np.random.RandomState(1)
    rids = [cb.submit(rs.randint(0, 32, size=int(rs.randint(3, 12))),
                      int(rs.randint(2, 8)))
            for _ in range(6)]
    rng, fin, steps = jax.random.PRNGKey(2), [], 0
    while cb.has_work():
        rng, f = cb.step(rng, strict=False)
        fin.extend(f)
        steps += 1
        assert steps < 2000, "allocator faults deadlocked the engine"
    assert sorted(r.rid for r in fin) == sorted(rids)
    assert all(r.error is None for r in fin)
    assert faults.fired["alloc_exhaust"] > 0
    assert len(cb.free_pages) + len(cb.page_refs) == cb.total_pages - 1


# ---------------------------------------------------------------------------
# SLO deadlines
# ---------------------------------------------------------------------------

def test_ttft_deadline_drops_queued_request(dense_env):
    """A queued request whose TTFT deadline passes while it waits is dropped
    before admission: it finishes with a deadline error, empty output, and
    no pages ever held."""
    dbm, params = dense_env
    cb = ContinuousBatcher(dbm, params, num_slots=1, **CB_KW)
    rs = np.random.RandomState(2)
    a = cb.submit(rs.randint(0, 32, size=8), 10)
    rng = jax.random.PRNGKey(3)
    rng, _ = cb.step(rng)                      # admit A; B will queue behind
    b = cb.submit(rs.randint(0, 32, size=8), 4, ttft_slo_s=0.001)
    time.sleep(0.01)
    fin = {}
    while cb.has_work():
        rng, f = cb.step(rng)
        fin.update({r.rid: r for r in f})
    assert fin[a].error is None and len(fin[a].out) == 10
    assert fin[b].deadline_blown and "ttft" in fin[b].error
    assert fin[b].out == [] and fin[b].pages == []
    assert cb.deadline_cancels == 1
    assert pool_whole(cb)


def test_tpot_deadline_retires_active_with_partial_output(dense_env):
    """An active request falling behind its TPOT pace is retired with the
    tokens it already produced — partial output delivered, slot and pages
    recycled."""
    dbm, params = dense_env
    cb = ContinuousBatcher(dbm, params, num_slots=1, **CB_KW)
    rid = cb.submit(np.arange(8, dtype=np.int32), 12, tpot_slo_s=1e-9)
    rng, fin = jax.random.PRNGKey(4), []
    while cb.has_work():
        rng, f = cb.step(rng)
        fin.extend(f)
    (req,) = fin
    assert req.rid == rid and req.deadline_blown
    assert "tpot" in req.error
    assert 2 <= len(req.out) < 12          # partial, not empty, not full
    assert cb.deadline_cancels == 1
    assert pool_whole(cb)


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------

def test_queue_depth_shed_is_class_aware(dense_env):
    """``max_queue`` sheds by CLASS-AWARE backlog: a standard submit is
    refused when enough equal-or-higher-priority work is queued, but an
    interactive submit still gets in (only interactive+ backlog counts
    against it). Shed carries a positive Retry-After hint."""
    dbm, params = dense_env
    cb = ContinuousBatcher(dbm, params, num_slots=1, max_queue=1, **CB_KW)
    rs = np.random.RandomState(3)
    cb.submit(rs.randint(0, 32, size=6), 3)            # queued (no step yet)
    with pytest.raises(AdmissionError) as ei:
        cb.submit(rs.randint(0, 32, size=6), 3)
    assert ei.value.retry_after > 0
    hi = cb.submit(rs.randint(0, 32, size=6), 3, priority="interactive")
    assert cb.shed_count == 1
    rng, fin = jax.random.PRNGKey(6), []
    while cb.has_work():
        rng, f = cb.step(rng)
        fin.extend(f)
    assert fin[0].rid == hi                 # priority order held
    assert len(fin) == 2 and all(r.error is None for r in fin)
    assert pool_whole(cb)


def test_pool_pressure_sheds_batch_only(dense_env):
    """``shed_below_pages`` refuses BATCH work when the free pool is thin;
    standard and interactive submissions are unaffected."""
    dbm, params = dense_env
    cb = ContinuousBatcher(dbm, params, num_slots=1,
                           shed_below_pages=10_000, **CB_KW)
    with pytest.raises(AdmissionError):
        cb.submit(np.arange(4, dtype=np.int32), 2, priority="batch")
    cb.submit(np.arange(4, dtype=np.int32), 2)          # standard: accepted
    assert cb.shed_count == 1 and len(cb.queue) == 1


def test_unknown_priority_rejected(dense_env):
    dbm, params = dense_env
    cb = ContinuousBatcher(dbm, params, num_slots=1, **CB_KW)
    with pytest.raises(ValueError):
        cb.submit(np.arange(4, dtype=np.int32), 2, priority="vip")
