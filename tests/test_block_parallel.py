"""Block-parallel engine (repro.parallel): stacked-view round-trips, exact
agreement with the sequential per-block trainer, periphery sync policies,
the round-robin fallback schedule, and per-block optimizer checkpoints.

The multi-device tests need a pod per block; CI provides them via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (they skip on a plain
1-device run — the fallback-path tests still cover the shared math there)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import DBConfig
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import DiffusionBlocksModel
from repro.core.training import extract_block_view, make_db_train_step
from repro.data import arithmetic_stream
from repro.parallel import (BlockParallelTrainer, merge_params,
                            split_periphery, stack_block_views)

TINY8 = ModelConfig(name="tiny8", family="dense", n_layers=8, d_model=64,
                    n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64)
B = 4

needs_pods = pytest.mark.skipif(
    jax.device_count() < B,
    reason=f"needs >= {B} devices "
           f"(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


@pytest.fixture(scope="module")
def dbm():
    return DiffusionBlocksModel(TINY8, DBConfig(num_blocks=B,
                                                overlap_gamma=0.05))


@pytest.fixture(scope="module")
def params(dbm):
    return dbm.init(jax.random.PRNGKey(0))


def tcfg(steps=8, **kw):
    kw.setdefault("lr", 2e-3)
    kw.setdefault("warmup_steps", 2)
    kw.setdefault("log_every", 0)
    return TrainConfig(steps=steps, **kw)


def data_it(seed=0, batch=8, seq=16):
    s = seed
    while True:
        s += 1
        yield jnp.asarray(arithmetic_stream(batch, seq, 64, s))


def tree_equal(a, b, **tol):
    for (pa, x), (_, y) in zip(jax.tree_util.tree_flatten_with_path(a)[0],
                               jax.tree_util.tree_flatten_with_path(b)[0]):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   err_msg=str(pa), **tol)


# ---------------------------------------------------------------------------
# (a) stacked views round-trip; non-owned slices stay bit-exact
# ---------------------------------------------------------------------------
def test_stacked_view_roundtrip_bit_exact(dbm, params):
    stacks, periph = stack_block_views(params, dbm.ranges), \
        split_periphery(params)[1]
    back = merge_params(params, stacks, periph, dbm.ranges)
    tree_equal(back, params, atol=0, rtol=0)


def test_writeback_preserves_non_owned_slices(dbm, params):
    """Perturb ONE block's stacked slice; every other block's units must
    round-trip bit-exactly through extract → write_back."""
    stacks, periph = stack_block_views(params, dbm.ranges), \
        split_periphery(params)[1]
    victim = 2
    stacks2 = jax.tree_util.tree_map(
        lambda x: x.at[victim].add(1.0), stacks)
    back = merge_params(params, stacks2, periph, dbm.ranges)
    for b, (start, size) in enumerate(dbm.ranges):
        got = extract_block_view(back, start, size)
        ref = extract_block_view(params, start, size)
        for k in ("layers",):
            if b == victim:
                tree_equal(got[k],
                           jax.tree_util.tree_map(lambda x: x + 1.0, ref[k]),
                           atol=0, rtol=0)
            else:
                tree_equal(got[k], ref[k], atol=0, rtol=0)


def test_unequal_block_sizes_rejected():
    cfg = ModelConfig(name="tiny6", family="dense", n_layers=6, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64)
    dbm6 = DiffusionBlocksModel(cfg, DBConfig(num_blocks=4))   # 6 units / 4
    with pytest.raises(ValueError, match="equal-sized"):
        BlockParallelTrainer(dbm6, tcfg())


# ---------------------------------------------------------------------------
# (b) parallel step/run ≡ sequential per-block training
# ---------------------------------------------------------------------------
@needs_pods
def test_parallel_step_matches_sequential_per_block(dbm, params):
    """One shard_map step (data=1 for bit-reproducible draws) must reproduce
    ``make_db_train_step``'s loss AND stack update for every block."""
    cfg = tcfg()
    tokens = jnp.asarray(arithmetic_stream(8, 16, 64, 1))
    key = jax.random.PRNGKey(7)
    tr = BlockParallelTrainer(dbm, cfg, devices=jax.devices()[:B])
    assert tr.mode == "shard_map" and dict(tr.mesh.shape)["data"] == 1
    state, losses, _ = tr.step(tr.init_state(params), tokens,
                               jnp.stack([key] * B))
    full = tr.full_params(state)
    for b in range(B):
        init_opt, step = make_db_train_step(dbm, b, cfg)
        p_ref, _, loss_ref, _ = step(params, init_opt(params), tokens, key,
                                     None)
        np.testing.assert_allclose(float(losses[b]), float(loss_ref),
                                   rtol=1e-5)
        start, size = dbm.ranges[b]
        tree_equal(extract_block_view(full, start, size)["layers"],
                   extract_block_view(p_ref, start, size)["layers"],
                   atol=1e-6, rtol=1e-6)


@needs_pods
def test_shard_map_trajectory_matches_round_robin(dbm):
    """The device-parallel engine and the round-robin fallback are the same
    algorithm: identical rng stream → per-block loss trajectories agree."""
    cfg = tcfg(steps=3 * B)
    kw = dict(rng=jax.random.PRNGKey(3), log=lambda *_: None)
    tr_p = BlockParallelTrainer(dbm, cfg, devices=jax.devices()[:B])
    tr_f = BlockParallelTrainer(dbm, cfg, devices=jax.devices()[:1])
    assert tr_p.mode == "shard_map" and tr_f.mode == "round_robin"
    _, hist_p = tr_p.train(data_it(), **kw)
    _, hist_f = tr_f.train(data_it(), **kw)
    assert len(hist_p) == len(hist_f) == 3 * B
    for (it_p, b_p, l_p), (it_f, b_f, l_f) in zip(hist_p, hist_f):
        assert (it_p, b_p) == (it_f, b_f)
        np.testing.assert_allclose(l_p, l_f, rtol=1e-4)


# ---------------------------------------------------------------------------
# (c) graceful degradation when devices < blocks
# ---------------------------------------------------------------------------
def test_fallback_schedule_when_devices_insufficient(dbm):
    tr = BlockParallelTrainer(dbm, tcfg(), devices=jax.devices()[:1])
    assert tr.mode == "round_robin" and tr.mesh is None
    _, hist = tr.train(data_it(), jax.random.PRNGKey(0), log=lambda *_: None)
    assert len(hist) == 8                       # ceil(steps/B) * B entries
    assert [b for _, b, _ in hist] == [0, 1, 2, 3, 0, 1, 2, 3]
    assert all(np.isfinite(l) for _, _, l in hist)


def test_train_db_parallel_entrypoint(dbm):
    from repro.core import train_db
    _, hist = train_db(dbm, tcfg(steps=B), data_it(), jax.random.PRNGKey(0),
                       log=lambda *_: None, parallel="blocks")
    assert len(hist) == B
    with pytest.raises(ValueError, match="parallel"):
        train_db(dbm, tcfg(steps=B), data_it(), jax.random.PRNGKey(0),
                 parallel="banana")


# ---------------------------------------------------------------------------
# periphery sync policies
# ---------------------------------------------------------------------------
def test_freeze_after_warmup_stops_periphery(dbm, params):
    tr = BlockParallelTrainer(dbm, tcfg(), periphery="freeze-after-warmup",
                              freeze_steps=1, devices=jax.devices()[:1])
    state = tr.init_state(params)
    it = data_it()
    key = jax.random.PRNGKey(1)
    s1, _, _ = tr.step(state, next(it), jax.random.split(key, B))
    # warmup step: periphery moved
    moved = any(not np.allclose(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree_util.tree_leaves(s1.periph),
                                jax.tree_util.tree_leaves(state.periph)))
    assert moved
    s2, _, _ = tr.step(s1, next(it), jax.random.split(key, B))
    tree_equal(s2.periph, s1.periph, atol=0, rtol=0)   # frozen
    # ...but blocks keep training
    assert any(not np.allclose(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree_util.tree_leaves(s2.stacks),
                               jax.tree_util.tree_leaves(s1.stacks)))


def test_owner_broadcast_uses_owner_gradients_only(dbm, params):
    """Under owner-broadcast the periphery update must be exactly the AdamW
    step on the OWNER block's (clipped) periphery grads."""
    from repro.optim import apply_updates, clip_by_global_norm
    from repro.parallel.engine import _split_optimizer
    cfg = tcfg()
    tokens = jnp.asarray(arithmetic_stream(8, 16, 64, 1))
    key = jax.random.PRNGKey(9)
    tr = BlockParallelTrainer(dbm, cfg, periphery="owner-broadcast",
                              devices=jax.devices()[:1])
    state = tr.init_state(params)
    s1, _, _ = tr.step(state, tokens, jnp.stack([key] * B))

    owner = B - 1
    start, size = dbm.ranges[owner]
    view = extract_block_view(params, start, size)
    g = jax.grad(lambda v: dbm.block_loss(
        v, owner, tokens, key, unit_range=(0, size))[0])(view)
    g, _ = clip_by_global_norm(g, cfg.grad_clip)
    g_per = {k: v for k, v in g.items() if k not in ("layers", "units")}
    opt_init, opt_update = _split_optimizer(cfg)
    popt = opt_init(split_periphery(params)[1])
    upd, _, _ = opt_update(g_per, popt, split_periphery(params)[1])
    ref = apply_updates(split_periphery(params)[1], upd)
    tree_equal(s1.periph, ref, atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# per-block checkpoints from the mesh
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_per_block_opt(dbm, params, tmp_path):
    tr = BlockParallelTrainer(dbm, tcfg(), devices=jax.devices()[:1])
    state = tr.init_state(params)
    state, _, _ = tr.step(state, jnp.asarray(arithmetic_stream(8, 16, 64, 1)),
                          jax.random.split(jax.random.PRNGKey(2), B))
    tr.save_checkpoint(state, str(tmp_path), step=B)
    for b in range(B):
        assert (tmp_path / f"block_{b:02d}.npz").exists()
        assert (tmp_path / f"block_{b:02d}.opt.npz").exists()
    assert (tmp_path / "periphery.opt.npz").exists()
    restored = tr.restore(dbm.init(jax.random.PRNGKey(99)), str(tmp_path))
    tree_equal(restored.stacks, state.stacks, atol=1e-6, rtol=1e-6)
    tree_equal(restored.periph, state.periph, atol=1e-6, rtol=1e-6)
    tree_equal(restored.stack_opt, state.stack_opt, atol=1e-6, rtol=1e-6)
    tree_equal(restored.periph_opt, state.periph_opt, atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# periphery lr compensation (1-vs-B update-count gap)
# ---------------------------------------------------------------------------
def test_periphery_lr_scale_compensates_update_cadence():
    """With ``lr_scale=B`` the periphery optimizer's first update must be
    exactly B * sched(B) / sched(1) times the unscaled one: rate scaled by B
    AND the warmup/cosine schedule evaluated at the equivalent block-update
    count."""
    from repro.optim.schedules import warmup_cosine
    from repro.parallel.engine import _split_optimizer
    cfg = tcfg(steps=32)
    base_init, base_upd = _split_optimizer(cfg)
    comp_init, comp_upd = _split_optimizer(cfg, lr_scale=float(B))
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 0.5)}
    u_b, _, _ = base_upd(g, base_init(p), p)
    u_c, _, _ = comp_upd(g, comp_init(p), p)
    sched = warmup_cosine(cfg.lr, cfg.warmup_steps, cfg.steps)
    ratio = float(B * sched(jnp.asarray(1.0 * B)) / sched(jnp.asarray(1.0)))
    np.testing.assert_allclose(np.asarray(u_c["w"]) / np.asarray(u_b["w"]),
                               ratio, rtol=1e-5)


@pytest.mark.slow
def test_periphery_lr_compensation_convergence_parity(dbm):
    """Same data/rng, same per-block-update budget: the compensated engine's
    final losses must land strictly closer to the sequential trainer's than
    the uncompensated engine's (whose periphery moves B× too slowly), and
    within an absolute band of the sequential tail."""
    from repro.core import train_db
    cfg = tcfg(steps=6 * B)
    kw = dict(log=lambda *_: None)
    _, h_seq = train_db(dbm, cfg, data_it(), jax.random.PRNGKey(5), **kw)
    _, h_comp = train_db(dbm, cfg, data_it(), jax.random.PRNGKey(5),
                         parallel="blocks", periphery_lr_scale="auto", **kw)
    _, h_unc = train_db(dbm, cfg, data_it(), jax.random.PRNGKey(5),
                        parallel="blocks", **kw)
    tail = lambda h: float(np.mean([l for _, _, l in h[-2 * B:]]))  # noqa: E731
    t_seq, t_comp, t_unc = tail(h_seq), tail(h_comp), tail(h_unc)
    assert np.isfinite(t_comp)
    assert abs(t_comp - t_seq) < abs(t_unc - t_seq)
    assert abs(t_comp - t_seq) < 0.9
